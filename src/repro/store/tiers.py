"""Cold storage tiers for the artifact store (DESIGN.md §15).

The store's hierarchy is device → pinned host → local disk → remote
object store.  This module holds the two tiers that are NOT the
existing device cache / disk backend:

  * ``HostCache`` — a bytes-bounded LRU of numpy-resident column
    payloads.  The device cache demotes into it on eviction, so an
    artifact squeezed out of device memory is one host→device transfer
    away instead of a disk read (or a remote fetch).  Entries are pure
    caches: dropping one can never lose data.
  * ``RemoteObjectStore`` — an S3-style object store emulated on a
    local directory: whole-artifact blobs, atomic publish (tmp file +
    rename), per-request latency and bandwidth injection so benchmarks
    see realistic cold-fetch costs, and **batched** multi-object fetch
    (``get_many``/``head_many`` charge one round-trip for the batch —
    the reason a speculative prefetcher beats on-demand reads even
    when it fetches the same bytes).

Blob format (one object per artifact): a JSON header carrying the
artifact's manifest plus a column directory, followed by each data
file's columns individually compressed with the lossless columnar
codec in ``train/compression.py``.  Values round-trip bit-exactly —
the tier-transition property suite gates promote→demote→promote on
bit-identity, so a lossy codec is structurally impossible here.
"""
from __future__ import annotations

import collections
import io
import json
import os
import struct
import tempfile
import threading
import time
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from ..train.compression import decode_array, encode_array

_BLOB_MAGIC = b"RSB1"


# --------------------------------------------------------------- host tier
class HostCache:
    """Bytes-bounded LRU of host-resident artifact payloads.

    A payload is ``{col: np.ndarray, "__valid__": np.ndarray}`` — the
    exact arrays a Table rebuilds from with one ``jnp.asarray`` per
    column.  Thread-safe: the device cache demotes from whichever
    thread triggered the eviction (engine or flusher)."""

    def __init__(self, max_bytes: int):
        self.max_bytes = int(max_bytes)
        self._entries: "collections.OrderedDict[str, Tuple[dict, int]]" = \
            collections.OrderedDict()
        self._lock = threading.Lock()
        self.total_bytes = 0
        self.hits = 0
        self.misses = 0

    @staticmethod
    def payload_nbytes(payload: dict) -> int:
        return sum(int(a.nbytes) for a in payload.values())

    def put(self, name: str, payload: dict,
            nbytes: Optional[int] = None) -> None:
        nb = self.payload_nbytes(payload) if nbytes is None else int(nbytes)
        with self._lock:
            if name in self._entries:
                self.total_bytes -= self._entries.pop(name)[1]
            if nb > self.max_bytes:
                return                    # oversized: not cacheable here
            self._entries[name] = (payload, nb)
            self.total_bytes += nb
            while self.total_bytes > self.max_bytes and len(self._entries) > 1:
                _, (_p, n) = self._entries.popitem(last=False)
                self.total_bytes -= n

    def get(self, name: str) -> Optional[dict]:
        with self._lock:
            ent = self._entries.get(name)
            if ent is None:
                self.misses += 1
                return None
            self._entries.move_to_end(name)
            self.hits += 1
            return ent[0]

    def drop(self, name: str) -> None:
        with self._lock:
            ent = self._entries.pop(name, None)
            if ent is not None:
                self.total_bytes -= ent[1]

    def recount(self) -> int:
        """Independent ledger recount (the accounting audits assert
        ``total_bytes == recount()``)."""
        with self._lock:
            return sum(nb for _p, nb in self._entries.values())

    def __contains__(self, name: str) -> bool:
        with self._lock:
            return name in self._entries

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)


# ----------------------------------------------------------- blob encoding
def encode_artifact_blob(manifest: dict,
                         files: Dict[str, Dict[str, np.ndarray]],
                         level: int = 1) -> bytes:
    """Pack an artifact (manifest + per-file column arrays) into one
    self-describing blob.  Columns are compressed independently so the
    directory in the header can say exactly what a ranged read would
    need — and so corruption is detectable per column (crc32 of the
    encoded bytes)."""
    import zlib
    directory: List[dict] = []
    payloads: List[bytes] = []
    off = 0
    for fname in sorted(files):
        for col in sorted(files[fname]):
            enc = encode_array(files[fname][col], level)
            directory.append({"file": fname, "col": col, "off": off,
                              "len": len(enc), "crc": zlib.crc32(enc)})
            payloads.append(enc)
            off += len(enc)
    header = json.dumps({"manifest": manifest,
                         "columns": directory}).encode()
    return (_BLOB_MAGIC + struct.pack("<I", len(header)) + header
            + b"".join(payloads))


def decode_blob_header(blob: bytes) -> dict:
    if blob[:4] != _BLOB_MAGIC:
        raise ValueError("artifact blob: bad magic")
    (hlen,) = struct.unpack_from("<I", blob, 4)
    return json.loads(blob[8:8 + hlen].decode())


def decode_artifact_blob(blob: bytes, verify: bool = True
                         ) -> Tuple[dict, Dict[str, Dict[str, np.ndarray]]]:
    """Inverse of ``encode_artifact_blob``; raises ValueError on any
    structural or checksum damage (the caller quarantines)."""
    import zlib
    head = decode_blob_header(blob)
    (hlen,) = struct.unpack_from("<I", blob, 4)
    base = 8 + hlen
    files: Dict[str, Dict[str, np.ndarray]] = {}
    for ent in head["columns"]:
        raw = blob[base + ent["off"]:base + ent["off"] + ent["len"]]
        if len(raw) != ent["len"]:
            raise ValueError("artifact blob: truncated payload")
        if verify and zlib.crc32(raw) != ent["crc"]:
            raise ValueError(f"artifact blob: column {ent['col']!r} "
                             f"checksum mismatch")
        files.setdefault(ent["file"], {})[ent["col"]] = decode_array(raw)
    return head["manifest"], files


def verify_blob(blob: bytes) -> bool:
    try:
        decode_artifact_blob(blob, verify=True)
        return True
    except Exception:
        return False


# ---------------------------------------------------------- remote tier
class RemoteObjectStore:
    """Local-directory emulation of an S3-like object store.

    One file per object, atomic publish (write to ``.tmp-*`` then
    rename), injectable per-request latency and bandwidth so cold
    fetches cost what a real remote costs.  Batched operations charge
    ONE latency for the whole batch — the economics that make
    speculative prefetch (which batches) beat demand paging (which
    cannot)."""

    def __init__(self, root: str, latency_s: float = 0.0,
                 bandwidth_bytes_s: Optional[float] = None):
        self.root = root
        self.latency_s = float(latency_s)
        self.bandwidth_bytes_s = bandwidth_bytes_s
        os.makedirs(root, exist_ok=True)
        self.stats = {"requests": 0, "objects_out": 0, "objects_in": 0,
                      "bytes_out": 0, "bytes_in": 0, "deletes": 0}
        self._lock = threading.Lock()

    # names reuse the store's injective dir encoding via the caller; the
    # remote itself only needs a flat, filesystem-safe key
    def path(self, key: str) -> str:
        return os.path.join(self.root, key + ".blob")

    def _charge(self, nbytes: int, n_requests: int = 1) -> None:
        d = self.latency_s * n_requests
        if self.bandwidth_bytes_s:
            d += nbytes / self.bandwidth_bytes_s
        if d > 0:
            time.sleep(d)

    def put_object(self, key: str, data: bytes) -> str:
        """Atomically publish ``data`` under ``key``; returns the final
        path (the store's fault choke point corrupts through it)."""
        self._charge(len(data))
        fd, tmp = tempfile.mkstemp(dir=self.root, prefix=".tmp-")
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(data)
            os.rename(tmp, self.path(key))
        except BaseException:
            # SimulatedCrash cannot reach here (raised by the caller's
            # choke points), so any failure mid-write reaps the tmp
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        with self._lock:
            self.stats["requests"] += 1
            self.stats["objects_in"] += 1
            self.stats["bytes_in"] += len(data)
        return self.path(key)

    def get_object(self, key: str) -> bytes:
        p = self.path(key)
        try:
            with open(p, "rb") as f:
                data = f.read()
        except FileNotFoundError:
            raise KeyError(key)
        self._charge(len(data))
        with self._lock:
            self.stats["requests"] += 1
            self.stats["objects_out"] += 1
            self.stats["bytes_out"] += len(data)
        return data

    def get_many(self, keys: Iterable[str]) -> Dict[str, bytes]:
        """Batched fetch: one latency charge for the whole batch,
        bandwidth on the summed bytes.  Missing keys are simply absent
        from the result (a prefetcher must tolerate races with
        deletes)."""
        out: Dict[str, bytes] = {}
        for k in keys:
            try:
                with open(self.path(k), "rb") as f:
                    out[k] = f.read()
            except FileNotFoundError:
                continue
        total = sum(len(v) for v in out.values())
        self._charge(total, n_requests=1)
        with self._lock:
            self.stats["requests"] += 1
            self.stats["objects_out"] += len(out)
            self.stats["bytes_out"] += total
        return out

    def head_many(self, keys: Iterable[str]) -> Dict[str, dict]:
        """Batched header read (the blob's JSON header only — an S3
        ranged GET): one latency charge, bandwidth on header bytes.
        Used by store open to index a remote population without paying
        a full cold fetch per artifact."""
        out: Dict[str, dict] = {}
        nbytes = 0
        for k in keys:
            try:
                with open(self.path(k), "rb") as f:
                    pre = f.read(8)
                    if len(pre) < 8 or pre[:4] != _BLOB_MAGIC:
                        continue
                    (hlen,) = struct.unpack_from("<I", pre, 4)
                    hdr = f.read(hlen)
            except OSError:
                continue
            try:
                out[k] = json.loads(hdr.decode())
            except ValueError:
                continue
            nbytes += 8 + len(hdr)
        self._charge(nbytes, n_requests=1)
        with self._lock:
            self.stats["requests"] += 1
            self.stats["bytes_out"] += nbytes
        return out

    def exists(self, key: str) -> bool:
        return os.path.exists(self.path(key))

    def delete(self, key: str) -> None:
        try:
            os.unlink(self.path(key))
        except FileNotFoundError:
            pass
        with self._lock:
            self.stats["deletes"] += 1

    def keys(self) -> List[str]:
        return sorted(fn[:-5] for fn in os.listdir(self.root)
                      if fn.endswith(".blob") and not fn.startswith(".tmp-"))

    def gc_tmp(self) -> int:
        """Reap orphaned ``.tmp-*`` upload files (a killed demotion
        leaks them, exactly like the disk tier's publish dirs)."""
        reaped = 0
        for fn in os.listdir(self.root):
            if fn.startswith(".tmp-"):
                try:
                    os.unlink(os.path.join(self.root, fn))
                    reaped += 1
                except OSError:
                    continue
        return reaped

    def total_bytes(self) -> int:
        return sum(os.path.getsize(self.path(k)) for k in self.keys())


def table_files_to_payloads(store_path: str, files: Iterable[str]
                            ) -> Dict[str, Dict[str, np.ndarray]]:
    """Read each npz data file of a published artifact into per-column
    arrays (host-side, no jax) — the demotion path's input."""
    out: Dict[str, Dict[str, np.ndarray]] = {}
    for fn in files:
        with open(os.path.join(store_path, fn), "rb") as f:
            z = np.load(io.BytesIO(f.read()))
        out[fn] = {n: z[n] for n in z.files}
    return out
