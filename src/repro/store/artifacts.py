"""Artifact store: the HDFS analogue, with a device-resident cache tier.

Stores Tables (and, through the checkpoint layer, arbitrary pytrees) under
content-addressed names.  Storage hierarchy (DESIGN.md §3):

  * **device cache** — a bytes-bounded LRU of live jax-array Tables in
    front of both backends.  ``get()`` of a recently produced artifact
    returns the device-resident arrays without touching numpy or disk
    (the M3R idea: intermediates served from memory, not the DFS);
  * in-memory backend — used by tests and CPU benchmarks (models
    Hadoop's case where intermediate data fits the page cache);
  * on-disk backend — one directory per artifact: ``data.npz`` +
    ``manifest.json`` (schema, capacity, row count, byte size, creation
    time).  Writes are **write-behind**: ``put()`` records metadata and
    caches the table synchronously, then a background flusher thread
    performs the device→host transfer and ``np.savez`` off the timed
    path.  Publication stays atomic (tmp dir + rename), so a killed
    writer never leaves a torn artifact — the fault-tolerance contract
    the checkpoint layer relies on.  ``flush()`` is the durability
    barrier: after it returns every accepted ``put`` is on disk.

Repeated ``put``s of the same name coalesce in the write queue (only the
newest version is flushed), so benchmark loops that re-store an artifact
per repetition pay for at most one disk write per name at a time.
"""
from __future__ import annotations

import atexit
import collections
import io
import json
import os
import shutil
import tempfile
import threading
import time
import zlib
from typing import Dict, Optional, Tuple

import numpy as np

from ..dataflow.table import (Table, concat_tables, partition_ids_device,
                              slice_valid)

# Default byte bound for the device-resident cache tier.
DEFAULT_CACHE_BYTES = int(os.environ.get("RESTORE_CACHE_BYTES",
                                         256 * 1024 * 1024))
# Bounded write-behind queue: puts block (backpressure) once this many
# distinct artifact names are waiting to be flushed.
DEFAULT_QUEUE_DEPTH = 64
# Orphaned ``.tmp-*`` publish dirs older than this are reaped when a
# store opens (DESIGN.md §13).  The age guard keeps a concurrently
# publishing process's live tmp dir safe; crash recovery, which knows
# no writer is alive, passes ``tmp_gc_age_s=0``.
DEFAULT_TMP_GC_AGE_S = float(os.environ.get("RESTORE_TMP_GC_AGE_S", 900))
# Transient-IO retry policy (capped exponential backoff).
READ_ATTEMPTS = 5
WRITE_ATTEMPTS = 4
RETRY_BASE_S = 0.002
RETRY_CAP_S = 0.1


class ArtifactError(Exception):
    """Base for artifact-level failures the driver can degrade around:
    reuse is an optimization, so every subclass maps to "quarantine the
    artifact and recompute cold" (DESIGN.md §13)."""

    def __init__(self, name: Optional[str], msg: Optional[str] = None):
        self.name = name
        super().__init__(msg or str(name))


class ArtifactMissingError(ArtifactError, KeyError):
    """Artifact not in the store (subclasses KeyError for callers of the
    pre-§13 API)."""


class CorruptArtifactError(ArtifactError):
    """On-disk bytes fail checksum/parse verification — deterministic
    damage, never retried, always quarantined."""


class TransientStoreError(ArtifactError):
    """IO kept failing after the capped-backoff retries."""


class ArtifactFlushError(ArtifactError, OSError):
    """One or more write-behind flushes failed permanently.  Raised by
    ``flush()`` — the durability barrier can never silently succeed
    after a failed write.  ``failures`` maps artifact name -> the
    exception that killed its write; the named artifacts have been
    de-advertised (a later run recomputes them).  Subclasses OSError:
    pre-§13 callers caught the propagated write error directly."""

    def __init__(self, failures: Dict[str, BaseException]):
        self.failures = dict(failures)
        ArtifactError.__init__(
            self, None, f"write-behind flush failed for "
                        f"{sorted(self.failures)}")


class SimulatedCrash(BaseException):
    """Raised by a FaultInjector to model process death mid-operation.
    Deliberately NOT an ``Exception``: retry wrappers must not absorb
    it, and the publish path must leave its tmp dir in place exactly
    like a real kill would (the crash-recovery suites assert the
    reopened store GCs it)."""


def _encode_name(name: str) -> str:
    """Injective artifact-name -> directory-name encoding.

    ``/`` is illegal in a path component so it becomes ``__``; a literal
    underscore is escaped to ``_u`` so names like ``art/q__v2`` survive a
    store re-open (the old ``replace("__", "/")`` decode corrupted them).
    """
    return name.replace("_", "_u").replace("/", "__")


def _decode_name(enc: str) -> str:
    out = []
    i = 0
    while i < len(enc):
        if enc.startswith("__", i):
            out.append("/")
            i += 2
        elif enc.startswith("_u", i):
            out.append("_")
            i += 2
        else:
            out.append(enc[i])
            i += 1
    return "".join(out)


def _pow2ceil(n: int) -> int:
    return 1 << (max(int(n), 1) - 1).bit_length()


def _npz_bytes(arrays: Dict[str, np.ndarray]) -> bytes:
    """Serialize arrays to npz bytes in memory, so the crc32 recorded in
    the manifest covers exactly the bytes written to disk."""
    buf = io.BytesIO()
    np.savez(buf, **arrays)
    return buf.getvalue()


def _partition_ids(table: Table, keys, n_parts: int) -> np.ndarray:
    """Host-side partition ids: the same ``partition_hash(keys) % P``
    the shard_map exchange computes (DESIGN.md §11) — store and executor
    must agree bit-for-bit on row placement."""
    return np.asarray(partition_ids_device(
        table, tuple(keys), int(n_parts))).astype(np.int64)


def _partition_layout(table: Table, keys, n_parts: int,
                      mask: Optional[np.ndarray] = None):
    """(pid, per-partition valid row counts, shard capacity) for storing
    ``table`` as ``n_parts`` equal-capacity partition shards.  Pass the
    host validity ``mask`` when the caller already transferred it —
    put() is on the timed store path and must not re-sync it."""
    pid = _partition_ids(table, keys, n_parts)
    if mask is None:
        mask = np.asarray(table.valid).astype(bool)
    counts = np.bincount(pid[mask], minlength=n_parts)
    m = int(counts.max()) if counts.size else 1
    # capacity granularity of 1/8th of the pow2 octave: padding stays
    # under 12.5% (a bare pow2 ceil doubles a 8193-row shard to 16384,
    # and every capacity-proportional op downstream with it) while the
    # shape-class count stays bounded for the jit cache
    g = max(8, _pow2ceil(max(m, 1)) // 8)
    shard_cap = max(8, -(-m // g) * g)
    return pid, counts, shard_cap


def _slice_partitions(host_cols: Dict[str, np.ndarray], mask: np.ndarray,
                      pid: np.ndarray, n_parts: int, shard_cap: int):
    """Slice host columns into per-partition blocks, each truncated and
    zero-padded to ``shard_cap`` rows.  The ONE implementation of the
    block layout — the sharded writer and re-partition-on-read must
    stay bit-identical.  One stable argsort of the partition ids, then
    per-partition view slicing: O(n log n), not O(n * n_parts) mask
    rescans (a 256-shard production mesh would scan the table 256x).
    Returns ({col: [block per partition]}, [valid rows per partition]).
    """
    rows = np.flatnonzero(mask)
    pr = pid[rows]
    order = np.argsort(pr, kind="stable")     # within-partition row order
    rows_s, pr_s = rows[order], pr[order]
    starts = np.searchsorted(pr_s, np.arange(n_parts))
    rank = np.arange(len(rows_s)) - starts[pr_s.astype(np.intp)]
    keep = rank < shard_cap                   # truncate overfull shards
    pos = (pr_s * shard_cap + rank)[keep]
    rows_k = rows_s[keep]
    counts = [int(c) for c in
              np.minimum(np.bincount(pr_s, minlength=n_parts), shard_cap)]
    blocks: Dict[str, list] = {}
    for n, a in host_cols.items():
        out = np.zeros((n_parts * shard_cap,) + a.shape[1:], a.dtype)
        out[pos] = a[rows_k]
        blocks[n] = [out[p * shard_cap:(p + 1) * shard_cap]
                     for p in range(n_parts)]
    return blocks, counts


class DeviceCache:
    """Bytes-bounded LRU over live (device-resident) Tables.

    Thread-safe: the write-behind flusher swaps in the compacted version
    of an artifact after publishing it, concurrently with reader
    ``get``s on the engine thread.

    ``on_evict`` (optional callable ``(name, table, nbytes)``) is
    invoked for every entry squeezed out by byte pressure — the store
    demotes those to the pinned-host tier (DESIGN.md §15) and prunes
    derived-view metadata.  It fires AFTER the cache lock is released
    (callbacks touch other locks) and only for pressure evictions:
    explicit ``drop``/``drop_prefix`` mean the data is stale or deleted,
    which must not demote."""

    def __init__(self, max_bytes: int):
        self.max_bytes = max_bytes
        self._entries: "collections.OrderedDict[str, Tuple[Table, int]]" = \
            collections.OrderedDict()
        self._lock = threading.Lock()
        self.total_bytes = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.on_evict = None

    @property
    def bytes_used(self) -> int:
        return self.total_bytes

    def recount(self) -> int:
        """Independent recount of the byte ledger from the entries
        themselves.  The accounting audits assert
        ``total_bytes == recount()`` after mutation storms — a drifted
        ledger silently mis-sizes every eviction decision."""
        with self._lock:
            return sum(nb for _t, nb in self._entries.values())

    def get(self, name: str) -> Optional[Table]:
        with self._lock:
            ent = self._entries.get(name)
            if ent is None:
                self.misses += 1
                return None
            self._entries.move_to_end(name)
            self.hits += 1
            return ent[0]

    def _put_locked(self, name: str, table: Table, nbytes: int) -> list:
        """Insert/replace under the lock.  Returns the entries evicted
        by byte pressure so the caller can run ``on_evict`` outside the
        lock.  A replaced entry's bytes are subtracted before the new
        size is added — an append that grows an artifact through put()
        charges exactly the delta, never both versions."""
        evicted = []
        if name in self._entries:
            self.total_bytes -= self._entries.pop(name)[1]
        # an artifact larger than the whole cache is not cached at all —
        # but it still displaces nothing, so it is reported as one
        # eviction of itself (the host tier may hold what device cannot)
        if nbytes > self.max_bytes:
            self.evictions += 1
            return [(name, table, nbytes)]
        self._entries[name] = (table, nbytes)
        self._entries.move_to_end(name)
        self.total_bytes += nbytes
        while (self.total_bytes > self.max_bytes
               and len(self._entries) > 1):
            k, (t, nb) = self._entries.popitem(last=False)
            self.total_bytes -= nb
            self.evictions += 1
            evicted.append((k, t, nb))
        return evicted

    def _notify(self, evicted: list) -> None:
        cb = self.on_evict
        if cb is None:
            return
        for name, table, nb in evicted:
            try:
                cb(name, table, nb)
            except Exception:
                pass        # a demotion failure must never break a put

    def put(self, name: str, table: Table, nbytes: int):
        with self._lock:
            evicted = self._put_locked(name, table, nbytes)
        self._notify(evicted)

    def swap_if(self, name: str, expected: Optional[Table],
                table: Table, nbytes: int):
        """Atomically insert ``table`` only if the current entry is
        ``expected``: the flusher uses this so its compacted version can
        never clobber a newer put that raced past it.  An entry the LRU
        already evicted is NOT resurrected — re-inserting it would evict
        recently-used entries to make room for one nobody asked for
        (it is on disk now; the next get re-caches it on demand)."""
        with self._lock:
            ent = self._entries.get(name)
            if ent is None or ent[0] is not expected:
                return
            evicted = self._put_locked(name, table, nbytes)
        self._notify(evicted)

    def drop(self, name: str):
        with self._lock:
            ent = self._entries.pop(name, None)
            if ent is not None:
                self.total_bytes -= ent[1]

    def drop_prefix(self, prefix: str):
        """Drop every entry whose key starts with ``prefix`` (derived
        re-partitioned views of a deleted artifact)."""
        with self._lock:
            for k in [k for k in self._entries if k.startswith(prefix)]:
                self.total_bytes -= self._entries.pop(k)[1]

    def __contains__(self, name: str) -> bool:
        with self._lock:
            return name in self._entries

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)


class _WriteBehind:
    """Background flusher: bounded, coalescing queue of pending artifact
    writes.  The caller thread enqueues (table, meta); this thread does
    device→host transfer + np.savez + atomic rename."""

    def __init__(self, store: "ArtifactStore", max_depth: int):
        self._store = store
        self._max_depth = max_depth
        self._cv = threading.Condition()
        # name -> (table, meta, pid) — newest data wins
        self._jobs: Dict[str, Tuple] = {}
        self._order: "collections.deque[str]" = collections.deque()
        self._queued = set()
        self._writing: Optional[str] = None
        # name -> exception of a permanently failed write.  Tracked
        # per artifact so one bad write can't hide behind a later good
        # one: flush() raises ArtifactFlushError listing every failure
        # since the last barrier (DESIGN.md §13).  Healed by a
        # successful re-put of the same name, or by cancel/delete.
        self.failures: Dict[str, BaseException] = {}
        self._closed = False
        self._thread: Optional[threading.Thread] = None
        self.flushed_count = 0

    # ------------------------------------------------------------- caller
    def _ensure_thread(self):
        if self._thread is None:
            self._thread = threading.Thread(
                target=self._run, name="artifact-flusher", daemon=True)
            self._thread.start()
            # drain pending writes before interpreter shutdown kills the
            # daemon thread (callers should still flush() explicitly at
            # durability points)
            atexit.register(self._flush_quietly)

    def _flush_quietly(self):
        # atexit drain: failures are still *recorded* (and the artifacts
        # de-advertised by the flusher) — only the raise is suppressed,
        # with a stderr warning so a failed write is never invisible
        try:
            self.flush()
        except BaseException as e:
            import sys
            print(f"restore: write-behind flush failed at exit: {e!r}",
                  file=sys.stderr)

    def submit(self, name: str, table: Table, meta: dict, pid=None):
        with self._cv:
            if self._closed:
                raise RuntimeError("store is closed")
            while (len(self._order) >= self._max_depth
                   and name not in self._queued):
                self._cv.wait()
            self._jobs[name] = (table, meta, pid)
            if name not in self._queued:
                self._queued.add(name)
                self._order.append(name)
            self._ensure_thread()
            self._cv.notify_all()

    def pending(self, name: str) -> Optional[Table]:
        with self._cv:
            job = self._jobs.get(name)
            return job[0] if job is not None else None

    def cancel(self, name: str):
        """Drop a queued write and wait out any in-flight write of the
        same name (so delete() cannot race with a publish)."""
        with self._cv:
            self._jobs.pop(name, None)
            self.failures.pop(name, None)   # deleted names owe no report
            if name in self._queued:
                self._queued.discard(name)
                try:        # stale names must not count toward backpressure
                    self._order.remove(name)
                except ValueError:
                    pass
                self._cv.notify_all()
            while self._writing == name:
                self._cv.wait()

    def flush(self):
        """Durability barrier.  Returns only when the queue is drained
        AND every write since the last barrier succeeded; otherwise
        raises ArtifactFlushError naming each failed artifact (already
        de-advertised by the flusher).  Reported failures are cleared —
        each barrier reports what broke since the previous one."""
        with self._cv:
            while self._jobs or self._writing is not None:
                self._cv.wait()
            if self.failures:
                failed, self.failures = self.failures, {}
                raise ArtifactFlushError(failed)

    def close(self):
        try:
            self.flush()
        finally:
            with self._cv:
                self._closed = True
                self._cv.notify_all()
            if self._thread is not None:
                self._thread.join(timeout=5)
                # the atexit hook would otherwise pin the store (and its
                # device cache) in memory for the process lifetime
                atexit.unregister(self._flush_quietly)
                self._thread = None

    # ------------------------------------------------------------ flusher
    def _run(self):
        while True:
            with self._cv:
                while not self._order and not self._closed:
                    self._cv.wait()
                if self._closed and not self._order:
                    return
                name = self._order.popleft()
                self._queued.discard(name)
                job = self._jobs.get(name)
                if job is None:          # cancelled while queued
                    self._cv.notify_all()
                    continue
                self._writing = name
                self._cv.notify_all()
            err = None
            compacted = None
            for attempt in range(WRITE_ATTEMPTS):
                try:
                    compacted = self._store._write_to_disk(
                        name, job[0], job[1], pid=job[2])
                    err = None
                    break
                except OSError as e:     # transient IO: capped backoff
                    err = e
                    if attempt + 1 < WRITE_ATTEMPTS:
                        self._store.stats["write_retries"] += 1
                        time.sleep(min(RETRY_CAP_S,
                                       RETRY_BASE_S * (2 ** attempt)))
                except BaseException as e:
                    # SimulatedCrash and programming errors are not
                    # transient — never retried, surfaced at flush()
                    err = e
                    break
            with self._cv:
                if self._jobs.get(name) is job:
                    del self._jobs[name]     # no newer put superseded us
                    if compacted is not None:
                        self.failures.pop(name, None)   # healed
                        # swap the compacted table into the device cache
                        # so reuse paths see the truncated capacity —
                        # unless a newer put already cached fresher data
                        self._store.cache.swap_if(name, job[0], compacted,
                                                  job[1]["nbytes"])
                    elif err is not None:
                        # the write is lost (retries exhausted): record
                        # the failure for flush() and stop advertising
                        # the artifact, or later runs would "reuse" data
                        # that will never be on disk
                        self.failures[name] = err
                        self._store.meta.pop(name, None)
                        self._store.cache.drop(name)
                # a superseded job's failure is irrelevant — the newer
                # put will be written (or fail) on its own turn
                self._writing = None
                self.flushed_count += 1
                self._cv.notify_all()


# Derived re-partitioned views kept per base artifact: repeated probes
# with distinct n_parts (mesh resizes) must not accumulate views without
# bound — each is a full-size copy competing with real artifacts for
# device bytes, and its metadata used to leak even after the cache
# evicted the view.
DEFAULT_MAX_DERIVED_VIEWS = 4


class ArtifactStore:
    def __init__(self, root: Optional[str] = None,
                 cache_bytes: int = DEFAULT_CACHE_BYTES,
                 queue_depth: int = DEFAULT_QUEUE_DEPTH,
                 write_behind: bool = True,
                 fault_injector=None,
                 tmp_gc_age_s: float = DEFAULT_TMP_GC_AGE_S,
                 host_bytes: int = 0,
                 remote=None,
                 cost_model=None,
                 max_derived_views: int = DEFAULT_MAX_DERIVED_VIEWS):
        self.root = root
        self.mem: Dict[str, Table] = {}
        self.meta: Dict[str, dict] = {}
        self.aliases: Dict[str, str] = {}
        # service.faults.FaultInjector (or None): called at the IO choke
        # points ("read"/"write"/"publish"/"published" on the disk tier,
        # "remote_read"/"remote_write"/"remote_published" on the remote
        # tier) so the fault suites can model torn writes, crashes and
        # flaky IO without monkeypatching store internals (DESIGN.md §13)
        self.fault_injector = fault_injector
        self.tmp_gc_age_s = float(tmp_gc_age_s)
        # robustness counters (fault suites + service stats assert these)
        self.stats = {"quarantined": 0, "read_retries": 0,
                      "write_retries": 0, "tmp_gc": 0, "corrupt_on_open": 0,
                      "demotions": 0, "promotions": 0, "host_demotions": 0,
                      "remote_reconciled": 0}
        # guards compound metadata transitions (put's record-then-submit,
        # delete's cancel-then-unlink, alias rewrites, append's
        # read-merge-write) against concurrent service workers.  The
        # flusher thread must NEVER take this lock: delete() holds it
        # while waiting out an in-flight write.
        self._lock = threading.RLock()
        # measured transfer samples (bytes moved, seconds on the caller's
        # clock) — the repository cost model calibrates its PER-TIER
        # bandwidth estimates from these (DESIGN.md §9/§15).  put()
        # samples only the synchronous (on-critical-path) portion: with
        # write-behind that is exactly what materialization costs a job.
        # Loads are tagged by the tier that served them: disk reads under
        # load_*, device-cache/memory hits under memload_*, pinned-host
        # promotions under hostload_*, remote fetches under remoteload_*.
        # Blending tiers would let a few microsecond cache hits inflate
        # the bandwidth estimate and price cold reads at ~zero (or a
        # remote fetch drag the disk estimate to ~nothing).
        self._io = {"load_bytes": 0, "load_s": 0.0,
                    "memload_bytes": 0, "memload_s": 0.0,
                    "hostload_bytes": 0, "hostload_s": 0.0,
                    "remoteload_bytes": 0, "remoteload_s": 0.0,
                    "store_bytes": 0, "store_s": 0.0}
        self.cache = DeviceCache(cache_bytes)
        self.cache.on_evict = self._on_device_evict
        # pinned-host tier: numpy payloads demoted from device (§15)
        if host_bytes > 0:
            from .tiers import HostCache
            self.host = HostCache(host_bytes)
        else:
            self.host = None
        # remote object-store tier (tiers.RemoteObjectStore or None)
        self.remote = remote
        # duck-typed CostModel for admission/demotion pricing; optional —
        # passed in by the driver/service, never imported (store must not
        # depend on core)
        self.cost_model = cost_model
        self.max_derived_views = int(max_derived_views)
        # recent read log (name, tier) — the speculative prefetcher
        # mines this for popularity; deque ops are atomic under the GIL
        self.read_log: "collections.deque" = collections.deque(maxlen=1024)
        # effective partitioning of cached re-partitioned views
        # (keyed by the derived "<name>#repart..." cache names)
        self._repart_meta: Dict[str, dict] = {}
        # insertion order of live derived views per base artifact, the
        # bound's eviction order (oldest view goes first)
        self._derived_order: Dict[str, list] = {}
        self._wb = _WriteBehind(self, queue_depth) if write_behind else None
        if root:
            os.makedirs(root, exist_ok=True)
            self.gc_tmp(self.tmp_gc_age_s)
            for name in self._scan_disk():
                try:
                    self.meta[name] = self._read_manifest(name)
                except (json.JSONDecodeError, OSError, ValueError):
                    # a torn manifest means the artifact can never be
                    # loaded: reap it now rather than advertise it
                    self.stats["corrupt_on_open"] += 1
                    shutil.rmtree(self._path(name), ignore_errors=True)
        if self.remote is not None:
            self._reconcile_remote()

    def _resolve(self, name: str) -> str:
        seen = set()
        while name in self.aliases and name not in seen:
            seen.add(name)
            name = self.aliases[name]
        return name

    def alias(self, name: str, target: str):
        if name != target:
            with self._lock:
                self.aliases[name] = target

    # ------------------------------------------------------------------ disk
    def _path(self, name: str) -> str:
        return os.path.join(self.root, _encode_name(name))

    def _fault(self, point: str, name: str, path: Optional[str] = None):
        """Fault-injection choke point (no-op without an injector)."""
        if self.fault_injector is not None:
            self.fault_injector.on(point, name, path=path)

    def gc_tmp(self, age_s: Optional[float] = None) -> int:
        """Reap orphaned ``.tmp-*`` publish dirs older than ``age_s``
        seconds (a crashed writer leaks them forever otherwise).  The
        age guard protects a concurrently publishing process's live tmp
        dir; crash recovery, which knows no writer survived, passes 0."""
        if not self.root:
            return 0
        if age_s is None:
            age_s = self.tmp_gc_age_s
        now = time.time()
        reaped = 0
        for d in os.listdir(self.root):
            if not d.startswith(".tmp-"):
                continue
            p = os.path.join(self.root, d)
            try:
                if now - os.path.getmtime(p) < age_s:
                    continue
                shutil.rmtree(p)
                reaped += 1
            except OSError:
                continue        # racing writer published/cleaned it
        self.stats["tmp_gc"] += reaped
        return reaped

    def _scan_disk(self):
        out = []
        for d in os.listdir(self.root):
            if d.startswith(".tmp-"):    # unpublished write, never decode
                continue
            # ignore directories that don't round-trip the current
            # encoding (e.g. roots written before the `_`->`_u` escape):
            # opening a store must never crash on foreign layouts
            if _encode_name(_decode_name(d)) != d:
                continue
            if os.path.exists(os.path.join(self.root, d, "manifest.json")):
                out.append(_decode_name(d))
        return out

    def _read_manifest(self, name: str) -> dict:
        with open(os.path.join(self._path(name), "manifest.json")) as f:
            return json.load(f)

    def _write_to_disk(self, name: str, table: Table, meta: dict,
                       pid=None) -> Table:
        """Compact host-side, serialize, atomically publish one artifact.
        Runs on the flusher thread (write-behind) or inline
        (write_behind=False); either way a crash mid-write leaves only an
        unpublished tmp dir, never a torn artifact.  Returns the
        compacted table (numpy-backed) for the device-cache swap.

        Partitioned artifacts (``meta["partitioning"]``) are written as
        one ``shard_%05d.npz`` file per partition — each shard compacted
        to the common ``shard_capacity`` — instead of one ``data.npz``;
        the returned table concatenates the shards in partition order,
        i.e. exactly the block layout the mesh loader shards by
        (DESIGN.md §11)."""
        part = meta.get("partitioning")
        if part is not None:
            return self._write_sharded(name, table, meta, pid)
        packed = table.host_compact(meta["capacity"], meta["rows"])
        valid = packed.pop("__valid__")
        final = self._path(name)
        self._fault("write", name)
        tmp = tempfile.mkdtemp(dir=self.root, prefix=".tmp-")
        try:
            data = _npz_bytes(dict(__valid__=valid, **packed))
            # checksums land in the SAME meta dict put() advertised, so
            # in-memory readers and the disk manifest agree after flush
            meta["checksums"] = {"data.npz": zlib.crc32(data)}
            with open(os.path.join(tmp, "data.npz"), "wb") as f:
                f.write(data)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(meta, f)
            self._fault("publish", name, path=tmp)
            self._publish(tmp, final)
        except SimulatedCrash:
            raise   # a real kill leaves its tmp dir; the injected one must
        except Exception:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._fault("published", name, path=final)
        import jax.numpy as jnp
        return Table({n: jnp.asarray(a) for n, a in packed.items()},
                     jnp.asarray(valid))

    def _publish(self, tmp: str, final: str):
        """Atomically swap ``tmp`` into place.  An existing version is
        renamed aside first (itself atomic), so a concurrent reader
        never observes a half-deleted directory — the window where
        ``final`` does not exist is one rename wide, and the retrying
        reader rides over it."""
        if os.path.exists(final):
            aside = tempfile.mkdtemp(dir=self.root, prefix=".tmp-old-")
            os.rename(final, os.path.join(aside, "d"))
            os.rename(tmp, final)
            shutil.rmtree(aside, ignore_errors=True)
        else:
            os.rename(tmp, final)

    def _write_sharded(self, name: str, table: Table, meta: dict,
                       pid=None) -> Table:
        part = meta["partitioning"]
        n_parts, shard_cap = part["n_parts"], part["shard_capacity"]
        if pid is None:     # write_behind=False path recomputes inline
            pid = _partition_ids(table, part["keys"], n_parts)
        mask = np.asarray(table.valid).astype(bool)
        host = {n: np.asarray(c) for n, c in table.columns.items()}
        blocks, counts = _slice_partitions(host, mask, pid, n_parts,
                                           shard_cap)
        vblocks = [np.arange(shard_cap) < c for c in counts]
        final = self._path(name)
        self._fault("write", name)
        tmp = tempfile.mkdtemp(dir=self.root, prefix=".tmp-")
        try:
            checks = {}
            for p in range(n_parts):
                fn = f"shard_{p:05d}.npz"
                data = _npz_bytes(dict(
                    __valid__=vblocks[p],
                    **{n: blocks[n][p] for n in host}))
                checks[fn] = zlib.crc32(data)
                with open(os.path.join(tmp, fn), "wb") as f:
                    f.write(data)
            meta["checksums"] = checks
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(meta, f)
            self._fault("publish", name, path=tmp)
            self._publish(tmp, final)
        except SimulatedCrash:
            raise
        except Exception:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._fault("published", name, path=final)
        import jax.numpy as jnp
        return Table({n: jnp.asarray(np.concatenate(bs))
                      for n, bs in blocks.items()},
                     jnp.asarray(np.concatenate(vblocks)))

    # ------------------------------------------------------------------ api
    def exists(self, name: str) -> bool:
        name = self._resolve(name)
        if name in self.mem or name in self.cache or name in self.meta:
            return True
        if self.host is not None and name in self.host:
            return True
        if bool(self.root) and os.path.exists(
                os.path.join(self._path(name), "manifest.json")):
            return True
        return self.remote is not None and self.remote.exists(
            self._remote_key(name))

    def io_stats(self) -> dict:
        """Measured transfer totals for cost-model calibration.
        ``has_disk`` tells the calibrator whether memory samples may
        stand in for the load bandwidth (pure in-memory store) or must
        not (disk-backed store whose cache hits would otherwise be
        blended into the cold-read estimate)."""
        out = dict(self._io)
        out["has_disk"] = bool(self.root)
        return out

    # ------------------------------------------------------------ tiers
    def _remote_key(self, name: str) -> str:
        return _encode_name(name)

    def _on_device_evict(self, name: str, table: Table, nbytes: int):
        """Pressure-eviction hook from the device cache: derived views
        just drop their metadata (they are rebuildable); real artifacts
        demote their columns to the pinned-host tier so the next get is
        a host→device transfer, not a disk read (DESIGN.md §15)."""
        if "#repart" in name:
            self._repart_meta.pop(name, None)
            base = name.split("#repart", 1)[0]
            order = self._derived_order.get(base)
            if order and name in order:
                order.remove(name)
            return
        if self.host is None or name not in self.meta:
            return
        if self.cost_model is not None and not self._admit_host(name, nbytes):
            return
        payload = {n: np.asarray(c) for n, c in table.columns.items()}
        payload["__valid__"] = np.asarray(table.valid)
        self.host.put(name, payload)
        self.stats["host_demotions"] += 1

    def _admit_host(self, name: str, nbytes: int) -> bool:
        """Price host admission with the attached cost model: demote
        only when re-reading from the serving tier below (disk or
        remote) would cost more than the host round-trip saves.  With
        no model attached, always admit (the host tier is a cache —
        wrong answers cost time, never correctness)."""
        below = "remote" if (self.remote is not None
                             and self.remote.exists(self._remote_key(name))
                             and not (self.root and os.path.exists(
                                 os.path.join(self._path(name),
                                              "manifest.json")))) else "disk"
        try:
            return bool(self.cost_model.should_promote(nbytes, below, "host"))
        except Exception:
            return True

    def residency(self, name: str) -> Optional[str]:
        """The warmest tier currently able to serve ``name``:
        "device" / "host" / "memory" / "pending" / "disk" / "remote",
        or None when the artifact does not exist anywhere."""
        name = self._resolve(name)
        if name in self.cache:
            return "device"
        if self.host is not None and name in self.host:
            return "host"
        if name in self.mem:
            return "memory"
        if self._wb is not None and self._wb.pending(name) is not None:
            return "pending"
        if self.root and os.path.exists(
                os.path.join(self._path(name), "manifest.json")):
            return "disk"
        if self.remote is not None and self.remote.exists(
                self._remote_key(name)):
            return "remote"
        return None

    def authoritative_tier(self, name: str) -> Optional[str]:
        """The durable tier that OWNS the artifact's bytes ("disk",
        "remote", "memory", or "pending" while a write-behind flush is
        in flight).  The tier-transition property suite asserts this is
        always exactly one of disk/remote for flushed artifacts —
        device/host copies are caches, never owners."""
        name = self._resolve(name)
        on_disk = bool(self.root) and os.path.exists(
            os.path.join(self._path(name), "manifest.json"))
        on_remote = self.remote is not None and self.remote.exists(
            self._remote_key(name))
        if on_disk and on_remote:
            return "conflict"        # only reachable mid-crash; reopen heals
        if on_disk:
            return "disk"
        if on_remote:
            return "remote"
        if name in self.mem:
            return "memory"
        if self._wb is not None and self._wb.pending(name) is not None:
            return "pending"
        return None

    def _reconcile_remote(self) -> None:
        """Open-time reconciliation of the disk/remote ownership
        invariant after a crash mid-transition (DESIGN.md §15).  Rule:
        a verified remote copy wins — a crash between remote publish
        and local delete was a *demotion about to commit*, so the lower
        tier's copy becomes authoritative (the satellite contract); an
        unverifiable remote blob is garbage from a torn upload and is
        deleted, leaving the disk copy authoritative.  Remote-only
        artifacts are indexed via one batched header fetch, so a cold
        open pays a single round-trip, not one per artifact."""
        self.remote.gc_tmp()
        keys = self.remote.keys()
        if not keys:
            return
        from .tiers import verify_blob
        heads = self.remote.head_many(keys)
        for key in keys:
            name = _decode_name(key)
            on_disk = bool(self.root) and os.path.exists(
                os.path.join(self._path(name), "manifest.json"))
            if on_disk:
                try:
                    ok = verify_blob(self.remote.get_object(key))
                except KeyError:
                    continue
                if ok:
                    shutil.rmtree(self._path(name), ignore_errors=True)
                    self.meta.pop(name, None)
                else:
                    self.remote.delete(key)
                    continue
                self.stats["remote_reconciled"] += 1
            head = heads.get(key)
            if head is None:
                # unreadable header: torn blob with no disk copy either
                # way — if disk survived we already kept it above;
                # otherwise the artifact is lost and must not advertise
                if not (self.root and os.path.exists(
                        os.path.join(self._path(name), "manifest.json"))):
                    self.remote.delete(key)
                    self.stats["corrupt_on_open"] += 1
                continue
            m = dict(head["manifest"])
            m["tier"] = "remote"
            self.meta[name] = m

    def demote_to_remote(self, name: str) -> dict:
        """Move a disk-resident artifact to the remote tier: package
        its data files column-compressed into one blob, publish it
        atomically, THEN remove the local copy.  Crash windows resolve
        at reopen via ``_reconcile_remote`` — before remote publish the
        disk copy is untouched; after it, the remote copy is
        authoritative.  Returns the updated meta."""
        from .tiers import encode_artifact_blob, table_files_to_payloads
        name = self._resolve(name)
        with self._lock:
            self.flush()                 # the disk copy must be complete
            m = self.meta.get(name)
            if m is None or not self.root or not os.path.exists(
                    os.path.join(self._path(name), "manifest.json")):
                raise ArtifactMissingError(name)
            if self.remote is None:
                raise ArtifactError(name, "store has no remote tier")
            part = m.get("partitioning")
            files = ([f"shard_{p:05d}.npz" for p in range(part["n_parts"])]
                     if part is not None else ["data.npz"])
            manifest = self._read_manifest(name)
            payloads = table_files_to_payloads(self._path(name), files)
            blob = encode_artifact_blob(manifest, payloads)
            key = self._remote_key(name)
            self._fault("remote_write", name)
            blob_path = self.remote.put_object(key, blob)
            # the commit point: a crash BEFORE this fault leaves both
            # copies (reopen completes the demotion); the local delete
            # below finishes it in-process
            self._fault("remote_published", name, path=blob_path)
            shutil.rmtree(self._path(name), ignore_errors=True)
            m = dict(manifest)
            m["tier"] = "remote"
            self.meta[name] = m
            # the device/host copies remain valid caches of the same
            # bytes; drop nothing
            self.stats["demotions"] += 1
            return m

    def promote_from_remote(self, name: str) -> dict:
        """Rehydrate a remote artifact onto local disk (atomic publish,
        fresh checksums — npz serialization is not byte-stable, values
        are), then delete the remote copy so exactly one durable tier
        owns it.  A crash between local publish and remote delete
        leaves both; reopen's verified-remote-wins rule re-demotes,
        which is safe (never lossy) and retried on next access."""
        name = self._resolve(name)
        with self._lock:
            if self.remote is None:
                raise ArtifactError(name, "store has no remote tier")
            if not self.root:
                raise ArtifactError(name, "store has no disk tier")
            key = self._remote_key(name)
            manifest, files = self._fetch_remote(name, key)
            final = self._path(name)
            tmp = tempfile.mkdtemp(dir=self.root, prefix=".tmp-")
            try:
                checks = {}
                for fn, cols in sorted(files.items()):
                    data = _npz_bytes(cols)
                    checks[fn] = zlib.crc32(data)
                    with open(os.path.join(tmp, fn), "wb") as f:
                        f.write(data)
                manifest = dict(manifest)
                manifest["checksums"] = checks
                manifest.pop("tier", None)
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump(manifest, f)
                self._fault("publish", name, path=tmp)
                self._publish(tmp, final)
            except SimulatedCrash:
                raise
            except Exception:
                shutil.rmtree(tmp, ignore_errors=True)
                raise
            self._fault("published", name, path=final)
            self.remote.delete(key)
            self.meta[name] = manifest
            self.stats["promotions"] += 1
            return manifest

    def _fetch_remote(self, name: str, key: str):
        """Fetch + decode one remote blob (fault-injectable; checksum
        damage quarantines like the disk tier's)."""
        from .tiers import decode_artifact_blob
        self._fault("remote_read", name)
        try:
            blob = self.remote.get_object(key)
        except KeyError:
            raise ArtifactMissingError(name)
        try:
            return decode_artifact_blob(blob)
        except ValueError as e:
            raise CorruptArtifactError(name, f"remote blob: {e}")

    def _table_from_payloads(self, manifest: dict, files: dict) -> Table:
        import jax.numpy as jnp
        part = manifest.get("partitioning")
        order = ([f"shard_{p:05d}.npz" for p in range(part["n_parts"])]
                 if part is not None else sorted(files))
        cols: Dict[str, list] = {}
        valids = []
        for fn in order:
            z = files[fn]
            valids.append(z["__valid__"])
            for n, a in z.items():
                if n != "__valid__":
                    cols.setdefault(n, []).append(a)
        return Table({n: jnp.asarray(np.concatenate(bs))
                      for n, bs in cols.items()},
                     jnp.asarray(np.concatenate(valids)))

    def _load_remote(self, name: str) -> Table:
        key = self._remote_key(name)
        manifest, files = self._fetch_remote(name, key)
        m = dict(manifest)
        m["tier"] = "remote"
        self.meta.setdefault(name, m)
        t = self._table_from_payloads(manifest, files)
        # priced promotion: rehydrate to disk when the model predicts
        # future reads make the (cheaper) disk tier worth the write
        if (self.cost_model is not None and self.root):
            try:
                if self.cost_model.should_promote(
                        m.get("nbytes", t.nbytes()), "remote", "disk"):
                    self.promote_from_remote(name)
            except (ArtifactError, OSError):
                pass        # promotion is an optimization, never required
        return t

    def prewarm(self, names) -> list:
        """Warm artifacts into the device (and host) caches ahead of a
        predicted probe — the speculative prefetcher's workhorse.
        Remote-resident artifacts are fetched with ONE batched request;
        authoritative tiers are untouched (warming is a cache fill, not
        a migration).  Returns the names actually warmed."""
        from .tiers import decode_artifact_blob
        warmed = []
        remote_batch = []
        for name in names:
            name = self._resolve(name)
            r = self.residency(name)
            if r in (None, "device"):
                continue
            if r == "remote":
                remote_batch.append(name)
                continue
            try:
                self.get(name)
                warmed.append(name)
            except ArtifactError:
                continue
        if remote_batch and self.remote is not None:
            blobs = self.remote.get_many(
                [self._remote_key(n) for n in remote_batch])
            for name in remote_batch:
                blob = blobs.get(self._remote_key(name))
                if blob is None:
                    continue
                try:
                    manifest, files = decode_artifact_blob(blob)
                except ValueError:
                    continue
                t = self._table_from_payloads(manifest, files)
                m = dict(manifest)
                m["tier"] = "remote"
                self.meta.setdefault(name, m)
                self.cache.put(name, t, t.nbytes())
                warmed.append(name)
        return warmed

    def drop_caches(self) -> int:
        """Release every cached (non-authoritative) copy: device
        entries, derived views (plus their metadata), and the pinned
        host tier.  Durable tiers are untouched — the next ``get``
        reloads from memory/disk/remote.  Models external memory
        pressure (other tenants claiming the accelerator between this
        stream's bursts); the tier benchmark uses it as the working-set
        flush that separates tenant bursts.  Returns entries dropped."""
        with self._lock:
            with self.cache._lock:
                names = list(self.cache._entries)
            n = len(names)
            for k in names:
                self.cache.drop(k)
                if "#repart" in k:
                    self._repart_meta.pop(k, None)
                    order = self._derived_order.get(
                        k.split("#repart", 1)[0])
                    if order and k in order:
                        order.remove(k)
            if self.host is not None:
                with self.host._lock:
                    hnames = list(self.host._entries)
                n += len(hnames)
                for k in hnames:
                    self.host.drop(k)
        return n

    def put(self, name: str, table: Table,
            partitioning: Optional[dict] = None) -> dict:
        """Store ``table`` under ``name``.

        ``partitioning`` (``{"keys": [...], "n_parts": P, "scheme":
        "hash_mod"}`` or a ``core.plan.Partitioning``) records the
        partition property of the value: the artifact is then written as
        P per-partition shard files (row r in shard ``hash(keys)(r) %
        P``), each compacted to a common power-of-2 shard capacity, and
        the property lands in the manifest so a consumer co-partitioned
        on the same keys can load it shuffle-free (DESIGN.md §11)."""
        t_start = time.perf_counter()
        name = self._resolve(name)
        # Stored artifacts shrink to the live row count (next power of 2):
        # this is what makes reusing a selective Filter/Project output
        # cheaper than recomputing it (paper Figs 16/17) — a stored HDFS
        # file is only as big as its rows.  The compaction itself happens
        # host-side on the flusher thread; the only on-clock work here is
        # one read of the (already synchronized) validity mask — a
        # zero-copy view on CPU, one small transfer on TPU — plus, for
        # partitioned artifacts, one pass of the partition hash.
        valid_mask = np.asarray(table.valid).astype(bool)
        nvalid = int(valid_mask.sum())
        pid = None
        if partitioning is not None:
            if hasattr(partitioning, "to_dict"):
                partitioning = partitioning.to_dict()
            part = {"keys": [str(k) for k in partitioning["keys"]],
                    "n_parts": int(partitioning["n_parts"]),
                    "scheme": partitioning.get("scheme", "hash_mod")}
            pid, counts, shard_cap = _partition_layout(
                table, part["keys"], part["n_parts"], mask=valid_mask)
            # the live table is served from the device cache as-is, so
            # the claimed property must already hold physically: valid
            # row r lives in block r // (capacity/P).  A violated claim
            # would let a consumer skip an exchange it actually needs.
            P_ = part["n_parts"]
            mask = valid_mask
            blk = table.capacity // P_ if table.capacity % P_ == 0 else 0
            if blk == 0 or not np.array_equal(
                    pid[mask], np.arange(table.capacity)[mask] // blk):
                raise ValueError(
                    f"put({name!r}): table layout does not match claimed "
                    f"partitioning {part['keys']} x {P_}")
            part["shard_capacity"] = int(shard_cap)
            part["shard_rows"] = [int(c) for c in counts]
            storecap = shard_cap * part["n_parts"]
        else:
            part = None
            storecap = min(table.capacity,
                           max(8, 1 << (max(nvalid, 1) - 1).bit_length()))
        # manifest capacity/nbytes describe the *stored* (compacted)
        # artifact, so they always agree with the data files on reload;
        # both are pure arithmetic over the schema — no data is touched
        nbytes = storecap
        for c in table.columns.values():
            width = int(c.shape[1]) if c.ndim == 2 else 1
            nbytes += c.dtype.itemsize * storecap * width
        meta = dict(name=name, capacity=storecap, rows=nvalid,
                    nbytes=int(nbytes), created=time.time())
        if part is not None:
            meta["partitioning"] = part
        # the compound record-then-submit transition is atomic w.r.t. a
        # concurrent delete()/quarantine() of the same name (service
        # workers share one store); the flusher never takes this lock
        with self._lock:
            # a re-put replaces the artifact's data, so any cached
            # re-partitioned views derived from the OLD data are stale now
            self._drop_derived(name)
            # cache the live (uncompacted) device table: the flusher swaps
            # in the compacted version once it is published.  meta is
            # recorded BEFORE submit so the flusher's failed-write
            # de-advertising (meta.pop) can never be overwritten by this
            # thread.
            self.cache.put(name, table, table.nbytes())
            self.meta[name] = meta
            try:
                if self.root:
                    if self._wb is not None:
                        self._wb.submit(name, table, meta, pid)
                    else:
                        compacted = self._write_to_disk(name, table, meta,
                                                        pid=pid)
                        self.cache.put(name, compacted, meta["nbytes"])
                else:
                    self.mem[name] = table
            except BaseException:
                # a failed put must not leave a phantom artifact
                self.cache.drop(name)
                self.meta.pop(name, None)
                raise
        self._io["store_bytes"] += meta["nbytes"]
        self._io["store_s"] += time.perf_counter() - t_start
        return meta

    def get(self, name: str) -> Table:
        """Serve ``name`` from the warmest tier holding it — device →
        pinned host → memory backend → pending write → disk → remote —
        promoting into the device cache on the way up and tagging the
        IO sample with the serving tier (DESIGN.md §15)."""
        t_start = time.perf_counter()
        name = self._resolve(name)
        hit = self.cache.get(name)
        if hit is not None:
            self._sample_load(name, t_start, tier="memload")
            return hit
        if self.host is not None:
            payload = self.host.get(name)
            if payload is not None:
                import jax.numpy as jnp
                cols = {n: jnp.asarray(a) for n, a in payload.items()
                        if n != "__valid__"}
                t = Table(cols, jnp.asarray(payload["__valid__"]))
                self.cache.put(name, t, t.nbytes())
                self._sample_load(name, t_start, tier="hostload")
                return t
        if name in self.mem:
            self._sample_load(name, t_start, tier="memload")
            return self.mem[name]
        if not self.root and self.remote is None:
            raise ArtifactMissingError(name)
        if self._wb is not None:
            pend = self._wb.pending(name)
            if pend is not None:         # evicted from cache, not yet on disk
                return pend
        if self.root and os.path.exists(
                os.path.join(self._path(name), "manifest.json")):
            t = self._load_disk_retry(name)
            self.cache.put(name, t, t.nbytes())
            self._sample_load(name, t_start, tier="load")
            return t
        if self.remote is not None and self.remote.exists(
                self._remote_key(name)):
            t = self._load_remote(name)
            self.cache.put(name, t, t.nbytes())
            self._sample_load(name, t_start, tier="remoteload")
            return t
        if self.root:
            # preserve the disk path's missing/corrupt classification
            # (and its retry ladder) for artifacts nothing else holds
            t = self._load_disk_retry(name)
            self.cache.put(name, t, t.nbytes())
            self._sample_load(name, t_start, tier="load")
            return t
        raise ArtifactMissingError(name)

    def _load_disk_retry(self, name: str) -> Table:
        """Disk load with capped-backoff retries over transient OSErrors
        (flaky IO, the one-rename publish window).  Deterministic damage
        (checksum/parse failure) and genuinely absent artifacts raise
        immediately — retrying cannot heal them."""
        last: Optional[BaseException] = None
        for attempt in range(READ_ATTEMPTS):
            try:
                return self._load_disk(name)
            except (ArtifactMissingError, CorruptArtifactError):
                raise
            except OSError as e:
                last = e
                if attempt + 1 < READ_ATTEMPTS:
                    self.stats["read_retries"] += 1
                    time.sleep(min(RETRY_CAP_S,
                                   RETRY_BASE_S * (2 ** attempt)))
        raise TransientStoreError(
            name, f"load({name!r}) failed after {READ_ATTEMPTS} "
                  f"attempts: {last!r}")

    def _load_disk(self, name: str) -> Table:
        self._fault("read", name)
        m = self.meta.get(name)
        if m is None:
            try:
                m = self.meta[name] = self._read_manifest(name)
            except FileNotFoundError:
                raise ArtifactMissingError(name)
            except (json.JSONDecodeError, ValueError) as e:
                raise CorruptArtifactError(
                    name, f"manifest unreadable: {e}")
        checks = m.get("checksums") or {}
        part = m.get("partitioning")
        import jax.numpy as jnp
        if part is not None:
            # sharded load: concatenating the shards in partition order
            # IS the mesh-ready block layout (shard i -> device i)
            cols: Dict[str, list] = {}
            valids = []
            for p in range(part["n_parts"]):
                fn = f"shard_{p:05d}.npz"
                z = self._read_npz_verified(name, fn, checks.get(fn))
                valids.append(z["__valid__"])
                for n in z.files:
                    if n != "__valid__":
                        cols.setdefault(n, []).append(z[n])
            return Table({n: jnp.asarray(np.concatenate(bs))
                          for n, bs in cols.items()},
                         jnp.asarray(np.concatenate(valids)))
        z = self._read_npz_verified(name, "data.npz",
                                    checks.get("data.npz"))
        return Table({n: jnp.asarray(z[n])
                      for n in z.files if n != "__valid__"},
                     jnp.asarray(z["__valid__"]))

    def _read_npz_verified(self, name: str, fname: str,
                           crc: Optional[int]):
        """Read one data file whole, crc-verify against the manifest
        (when recorded — pre-checksum artifacts still parse-check), and
        parse from memory.  Any mismatch is CorruptArtifactError: the
        caller quarantines and recomputes cold."""
        path = os.path.join(self._path(name), fname)
        try:
            with open(path, "rb") as f:
                data = f.read()
        except FileNotFoundError:
            if not os.path.exists(
                    os.path.join(self._path(name), "manifest.json")):
                raise ArtifactMissingError(name)   # whole artifact gone
            raise CorruptArtifactError(
                name, f"{fname} missing from published artifact")
        if crc is not None and zlib.crc32(data) != crc:
            raise CorruptArtifactError(
                name, f"{fname} checksum mismatch")
        try:
            return np.load(io.BytesIO(data))
        except Exception as e:      # BadZipFile / ValueError / pickle junk
            raise CorruptArtifactError(name, f"{fname} unreadable: {e}")

    def _drop_derived(self, name: str) -> None:
        """Invalidate cached ``<name>#repart...`` views (put/delete of
        the base artifact makes them stale)."""
        self.cache.drop_prefix(name + "#repart")
        for k in [k for k in self._repart_meta
                  if k.startswith(name + "#repart")]:
            del self._repart_meta[k]
        self._derived_order.pop(name, None)

    def _register_derived(self, name: str, ck: str, part: dict,
                          table: Table) -> None:
        """Record one derived re-partitioned view, bounded to
        ``max_derived_views`` live views per base artifact (oldest view
        evicted first).  Each view is a full-size copy of the artifact:
        probes cycling through distinct mesh sizes used to accumulate
        one copy per size, and the metadata leaked even after the
        device cache evicted the view's data."""
        with self._lock:
            order = self._derived_order.setdefault(name, [])
            # prune entries whose data the device cache already evicted
            # (the eviction hook cleared their metadata)
            order[:] = [k for k in order if k in self._repart_meta]
            if ck in order:
                order.remove(ck)
            while len(order) >= max(self.max_derived_views, 1):
                old = order.pop(0)
                self._repart_meta.pop(old, None)
                self.cache.drop(old)
            self._repart_meta[ck] = part
            order.append(ck)
        self.cache.put(ck, table, table.nbytes())

    def column_names(self, name: str) -> Tuple[str, ...]:
        """Column names of a stored artifact WITHOUT materializing it:
        cache/memory tables answer directly; on disk only the npz
        directory is read (lazy NpzFile — no data decompressed).  The
        mesh executor needs schemas for its static partition
        propagation, and a full load here would move T_load off the
        timed window (DESIGN.md §11)."""
        name = self._resolve(name)
        t = self.cache.get(name)
        if t is None:
            t = self.mem.get(name)
        if t is None and self._wb is not None:
            t = self._wb.pending(name)
        if t is not None:
            return tuple(t.names)
        if not self.root:
            raise ArtifactMissingError(name)
        part = self.partitioning(name)
        fn = "shard_00000.npz" if part is not None else "data.npz"
        path = os.path.join(self._path(name), fn)
        if not os.path.exists(path):
            raise ArtifactMissingError(name)
        try:
            with np.load(path) as z:
                return tuple(sorted(n for n in z.files
                                    if n != "__valid__"))
        except Exception as e:
            raise CorruptArtifactError(name, f"{fn} unreadable: {e}")

    # ------------------------------------------------------- partitioning
    def partitioning(self, name: str) -> Optional[dict]:
        """The stored partition property of an artifact (None when the
        artifact is monolithic or unknown)."""
        m = self.meta.get(self._resolve(name))
        return (m or {}).get("partitioning")

    def get_partitioned(self, name: str, keys, n_parts: int
                        ) -> Tuple[Table, dict]:
        """Load an artifact arranged for an exchange on ``keys`` across
        ``n_parts`` shards.  If the stored partitioning already covers
        the request it is returned as-is (the shuffle-free path); on a
        partition-count mismatch the table is re-partitioned host-side
        on read — one pass of the partition hash plus a gather, instead
        of a device exchange every time the artifact is consumed
        (DESIGN.md §11).  Returns (table, effective partitioning)."""
        name = self._resolve(name)
        keys = [str(k) for k in keys]
        stored = self.partitioning(name)
        if stored is not None and stored["n_parts"] == n_parts \
                and set(stored["keys"]) <= set(keys):
            return self.get(name), stored
        ck = f"{name}#repart{n_parts}:{','.join(keys)}"
        hit = self.cache.get(ck)
        if hit is not None and ck in self._repart_meta:
            return hit, self._repart_meta[ck]
        t = self.get(name)
        pid, _counts, shard_cap = _partition_layout(t, keys, n_parts)
        mask = np.asarray(t.valid).astype(bool)
        host = {n: np.asarray(c) for n, c in t.columns.items()}
        blocks, counts = _slice_partitions(host, mask, pid, n_parts,
                                           shard_cap)
        import jax.numpy as jnp
        cols = {n: jnp.asarray(np.concatenate(bs))
                for n, bs in blocks.items()}
        valid = jnp.asarray(np.concatenate(
            [np.arange(shard_cap) < c for c in counts]))
        t2 = Table(cols, valid)
        part = {"keys": keys, "n_parts": int(n_parts), "scheme": "hash_mod",
                "shard_capacity": int(shard_cap),
                "shard_rows": [int(c) for c in counts]}
        self._register_derived(name, ck, part, t2)
        return t2, part

    def _sample_load(self, name: str, t_start: float, tier: str):
        m = self.meta.get(name)
        if m is not None:
            self._io[tier + "_bytes"] += m["nbytes"]
            self._io[tier + "_s"] += time.perf_counter() - t_start
        if "#repart" not in name:
            self.read_log.append((name, tier))

    # ------------------------------------------------------------- refresh
    def append(self, name: str, delta: Table) -> dict:
        """Delta-refresh an artifact in place: merge ``delta``'s valid
        rows into the stored value (DESIGN.md §12).  Monolithic
        artifacts concatenate column-wise on device — an artifact's
        value is its valid rows, so holes need no compaction here (the
        disk path compacts on the flusher thread as always) and the
        merge is one memcpy-speed pass instead of a host round trip.
        Partitioned artifacts take the shard-local `merge_shards` path.
        Either way the write goes through `put`, which replaces the
        device-cache entry, coalesces over any pending write-behind job
        and invalidates every derived `get_partitioned` view of the old
        value — an in-place refresh must never leave a stale view
        servable."""
        name = self._resolve(name)
        # the read-merge-write must be atomic against a concurrent
        # append/merge of the same artifact (service workers share one
        # store): interleaved get→merge→put loses whichever delta
        # merged first.  RLock: put() retakes it reentrantly; the
        # flusher never takes it, so write-behind backpressure drains.
        with self._lock:
            if self.partitioning(name) is not None:
                return self.merge_shards(name, delta)
            old = self.get(name)
            if set(old.names) != set(delta.names):
                raise ValueError(f"append({name!r}): schema mismatch")
            import jax.numpy as jnp
            cols = {n: jnp.concatenate([old.col(n), delta.col(n)], axis=0)
                    for n in old.names}
            valid = jnp.concatenate([old.valid, delta.valid])
            return self.put(name, Table(cols, valid))

    def merge_shards(self, name: str, delta: Table, merge_fn=None) -> dict:
        """Shard-local refresh of a partitioned artifact: each ``delta``
        row is routed to its shard by the stored partition hash, and the
        shard is merged locally — pure append when ``merge_fn`` is None,
        else ``merge_fn(old_shard, delta_shard) -> Table`` (the
        re-aggregation operator of a refreshed GROUPBY/DISTINCT
        artifact, whose partition keys co-locate each group with its
        partial).  No cross-shard exchange happens: a co-partitioned
        artifact refreshes with the same locality its consumers exploit
        (DESIGN.md §11/§12).  The merged value is re-put under the same
        partition property, so the layout validation in `put` re-checks
        the claim."""
        name = self._resolve(name)
        self._lock.acquire()     # same atomicity contract as append()
        try:
            return self._merge_shards_locked(name, delta, merge_fn)
        finally:
            self._lock.release()

    def _merge_shards_locked(self, name: str, delta: Table,
                             merge_fn=None) -> dict:
        part = self.partitioning(name)
        if part is None:
            raise ValueError(
                f"merge_shards({name!r}): artifact is not partitioned")
        n_parts = int(part["n_parts"])
        old = self.get(name)
        shard_cap = old.capacity // n_parts
        names_ = old.names
        if set(delta.names) != set(names_):
            raise ValueError(f"merge_shards({name!r}): schema mismatch")
        pid = _partition_ids(delta, part["keys"], n_parts)
        dmask = np.asarray(delta.valid).astype(bool)
        dhost = {n: np.asarray(delta.col(n)) for n in names_}
        ohost = {n: np.asarray(old.col(n)) for n in names_}
        omask = np.asarray(old.valid).astype(bool)
        # per-shard delta tables share one capacity, so a jitted
        # merge_fn traces once instead of once per shard
        d_counts = np.bincount(pid[dmask], minlength=n_parts)
        dcap = max(8, _pow2ceil(int(d_counts.max()) if d_counts.size else 1))
        import jax.numpy as jnp
        merged_np = []
        for p in range(n_parts):
            sl = slice(p * shard_cap, (p + 1) * shard_cap)
            rows = np.flatnonzero(dmask & (pid == p))
            if merge_fn is None:
                m = {n: np.concatenate([ohost[n][sl][omask[sl]],
                                        dhost[n][rows]]) for n in names_}
            else:
                old_p = Table({n: jnp.asarray(ohost[n][sl])
                               for n in names_}, jnp.asarray(omask[sl]))
                delta_p = Table.from_numpy(
                    {n: dhost[n][rows] for n in names_}, capacity=dcap)
                mt = merge_fn(old_p, delta_p)
                mm = np.asarray(mt.valid).astype(bool)
                m = {n: np.asarray(mt.col(n))[mm] for n in names_}
            merged_np.append(m)
        counts = [len(next(iter(m.values()))) for m in merged_np]
        new_cap = max(8, _pow2ceil(max(counts) if counts else 1))
        blocks = {}
        for n in names_:
            padded = []
            for m in merged_np:
                a = m[n]
                pad = [(0, new_cap - len(a))] + [(0, 0)] * (a.ndim - 1)
                padded.append(np.pad(a, pad))
            blocks[n] = jnp.asarray(np.concatenate(padded))
        valid = jnp.asarray(np.concatenate(
            [np.arange(new_cap) < c for c in counts]))
        return self.put(name, Table(blocks, valid),
                        partitioning={"keys": list(part["keys"]),
                                      "n_parts": n_parts,
                                      "scheme": part.get("scheme",
                                                         "hash_mod")})

    def delete(self, name: str):
        with self._lock:
            # cancel the pending/in-flight write FIRST: the flusher
            # re-inserts the compacted table into the cache after
            # publishing, so dropping the cache entry before the cancel
            # could resurrect the artifact
            if self.root and self._wb is not None:
                self._wb.cancel(name)
            # drop any alias FROM this name: put() resolves aliases, so a
            # dangling mapping would silently redirect a later re-store of
            # the deleted name to the alias target
            self.aliases.pop(name, None)
            self.mem.pop(name, None)
            self.meta.pop(name, None)
            self.cache.drop(name)
            if self.host is not None:
                self.host.drop(name)
            # derived re-partitioned views of the artifact are stale too
            self._drop_derived(name)
            if self.root:
                p = self._path(name)
                if os.path.exists(p):
                    shutil.rmtree(p, ignore_errors=True)
            if self.remote is not None:
                self.remote.delete(self._remote_key(name))

    def quarantine(self, name: str):
        """Remove a damaged/missing artifact everywhere and count it.
        The caller (driver or recovery) then recomputes cold — reuse is
        an optimization, never a correctness dependency (DESIGN.md §13).
        """
        with self._lock:
            self.stats["quarantined"] += 1
            self.delete(name)

    def verify(self, name: str) -> bool:
        """Integrity check of the on-disk bytes of ``name`` — crc32 of
        every data file against the manifest (parse-check for
        pre-checksum artifacts) — without building a Table.  Journal
        recovery uses this to reconcile entries against what actually
        survived on disk."""
        name = self._resolve(name)
        if self.remote is not None and not (self.root and os.path.exists(
                os.path.join(self._path(name), "manifest.json"))):
            key = self._remote_key(name)
            if self.remote.exists(key):
                from .tiers import verify_blob
                try:
                    return verify_blob(self.remote.get_object(key))
                except KeyError:
                    return False
        if not self.root:
            return name in self.mem
        try:
            m = self._read_manifest(name)
        except (OSError, ValueError):
            return False
        checks = m.get("checksums") or {}
        part = m.get("partitioning")
        files = ([f"shard_{p:05d}.npz" for p in range(part["n_parts"])]
                 if part is not None else ["data.npz"])
        for fn in files:
            try:
                with open(os.path.join(self._path(name), fn), "rb") as f:
                    data = f.read()
            except OSError:
                return False
            crc = checks.get(fn)
            if crc is not None:
                if zlib.crc32(data) != crc:
                    return False
            else:
                try:
                    np.load(io.BytesIO(data)).close()
                except Exception:
                    return False
        return True

    def flush(self):
        """Durability barrier: returns once every accepted put() has been
        atomically published to disk (no-op for the memory backend)."""
        if self._wb is not None:
            self._wb.flush()

    def close(self):
        if self._wb is not None:
            self._wb.close()

    def nbytes(self, name: str) -> int:
        return self.meta[self._resolve(name)]["nbytes"]

    def total_bytes(self) -> int:
        return sum(m["nbytes"] for m in self.meta.values())

    def names(self):
        return sorted(self.meta)


class Catalog:
    """Source-dataset catalog with version stamps (eviction rule R4:
    modifying a dataset bumps its version, so old fingerprints never match
    and dependent artifacts are invalidated).

    Beyond the paper, the catalog distinguishes *append* deltas from
    arbitrary rewrites (DESIGN.md §12): ``append`` bumps the version like
    ``register`` but records the per-version valid-row count on an
    append lineage, so incremental maintenance can extract the delta
    rows (and the pre-append snapshot) of any version still on the
    lineage and refresh stale artifacts instead of R4-deleting them.
    ``register`` is an arbitrary rewrite and resets the lineage."""

    def __init__(self, store: ArtifactStore):
        self.store = store
        self.versions: Dict[str, int] = {}
        self.sources: Dict[str, Table] = {}
        # name -> [(version, n_valid_rows), ...] for the run of
        # consecutive append()s since the last register()
        self._lineage: Dict[str, list] = {}
        # datasets whose source table is prefix-valid (valid rows form
        # a leading contiguous block) — true by construction for
        # append()-built tables, and what lets delta/snapshot slicing
        # be a direct row-range view instead of an O(n) mask pass
        self._compact: set = set()

    def register(self, name: str, table: Table):
        self.versions[name] = self.versions.get(name, -1) + 1
        self.sources[name] = table
        self._compact.discard(name)
        n = int(np.asarray(table.valid).astype(bool).sum())
        self._lineage[name] = [(self.versions[name], n)]

    def append(self, name: str, delta: Table) -> int:
        """Append-only ingest: the new version extends the old one by
        exactly ``delta``'s valid rows, prefix-stable (the first n_old
        valid rows of the new version ARE the old version's rows).
        Returns the new version."""
        if name not in self.sources:
            raise KeyError(f"append to unregistered dataset {name!r}")
        merged = concat_tables([self.sources[name], delta])
        n = int(np.asarray(merged.valid).astype(bool).sum())
        v = self.versions.get(name, 0) + 1
        self.versions[name] = v
        self.sources[name] = merged
        self._compact.add(name)      # concat_tables output is compacted
        self._lineage.setdefault(name, [(v - 1, n - int(
            np.asarray(delta.valid).astype(bool).sum()))]).append((v, n))
        return v

    # -- append-lineage queries (incremental maintenance, DESIGN.md §12)
    def rows_at(self, name: str, version: int) -> Optional[int]:
        """Valid-row count of ``name`` at ``version``, or None when the
        version is not on the recorded append lineage."""
        for v, n in self._lineage.get(name, []):
            if v == version:
                return n
        return None

    def is_append_since(self, name: str, version: int) -> bool:
        """True iff the dataset's current version extends ``version`` by
        appends only (both versions on the recorded lineage)."""
        return self.rows_at(name, version) is not None

    def _slice_rows(self, name: str, lo: int,
                    hi: Optional[int], cols) -> Table:
        """Valid rows [lo:hi] of a source.  A prefix-valid (append-built)
        table slices by direct row range — a view plus one small copy —
        instead of slice_valid's mask pass.  Capacities round to the
        next power of two: real append sizes vary run to run, and an
        exact capacity would hand the delta plan a fresh input shape
        (and a full jit retrace) per refresh."""
        t = self.sources[name]
        if name not in self._compact:
            return slice_valid(t, lo, hi, cols=cols, round_pow2=True)
        names = t.names if cols is None else sorted(cols)
        out = {n: np.asarray(t.col(n))[lo:hi] for n in names}
        nvalid = len(out[names[0]])
        cap = 1 << (max(nvalid, 8) - 1).bit_length()
        return Table.from_numpy(out, nvalid=nvalid, capacity=cap)

    def delta_table(self, name: str, version: int,
                    cols=None) -> Optional[Table]:
        """The rows appended since ``version`` (None off-lineage).
        ``cols`` restricts to the columns the consumer needs."""
        n_old = self.rows_at(name, version)
        n_cur = self.rows_at(name, self.version(name))
        if n_old is None or n_cur is None:
            return None
        # explicit upper bound: a compact table may carry a few invalid
        # padding rows past n_cur (min-capacity floor), which a direct
        # row-range slice must not resurrect
        return self._slice_rows(name, n_old, n_cur, cols)

    def snapshot_table(self, name: str, version: int,
                       cols=None) -> Optional[Table]:
        """The dataset as it was at ``version`` (prefix snapshot)."""
        n_old = self.rows_at(name, version)
        if n_old is None:
            return None
        return self._slice_rows(name, 0, n_old, cols)

    def delta_fraction(self, name: str, version: int) -> float:
        """Appended rows as a fraction of the base at ``version``."""
        n_old = self.rows_at(name, version)
        n_cur = self.rows_at(name, self.version(name))
        if n_old is None or n_cur is None:
            return 1.0
        return (n_cur - n_old) / max(n_old, 1)

    def version(self, name: str) -> int:
        return self.versions.get(name, 0)

    def get(self, name: str) -> Table:
        if name in self.sources:
            return self.sources[name]
        return self.store.get(name)

    def has(self, name: str) -> bool:
        return name in self.sources or self.store.exists(name)
