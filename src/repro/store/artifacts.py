"""Artifact store: the HDFS analogue.

Stores Tables (and, through the checkpoint layer, arbitrary pytrees) under
content-addressed names.  Two backends:

  * in-memory — used by tests and CPU benchmarks (models Hadoop's case
    where intermediate data fits the page cache);
  * on-disk  — one directory per artifact: ``data.npz`` + ``manifest.json``
    (schema, capacity, row count, byte size, creation time).  Writes are
    atomic (tmp dir + rename) so a killed writer never leaves a torn
    artifact — the fault-tolerance contract the checkpoint layer relies on.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time
from typing import Dict, Optional

import numpy as np

from ..dataflow.table import Table


class ArtifactStore:
    def __init__(self, root: Optional[str] = None):
        self.root = root
        self.mem: Dict[str, Table] = {}
        self.meta: Dict[str, dict] = {}
        self.aliases: Dict[str, str] = {}
        if root:
            os.makedirs(root, exist_ok=True)
            for name in self._scan_disk():
                self.meta[name] = self._read_manifest(name)

    def _resolve(self, name: str) -> str:
        seen = set()
        while name in self.aliases and name not in seen:
            seen.add(name)
            name = self.aliases[name]
        return name

    def alias(self, name: str, target: str):
        if name != target:
            self.aliases[name] = target

    # ------------------------------------------------------------------ disk
    def _path(self, name: str) -> str:
        return os.path.join(self.root, name.replace("/", "__"))

    def _scan_disk(self):
        out = []
        for d in os.listdir(self.root):
            if os.path.exists(os.path.join(self.root, d, "manifest.json")):
                out.append(d.replace("__", "/"))
        return out

    def _read_manifest(self, name: str) -> dict:
        with open(os.path.join(self._path(name), "manifest.json")) as f:
            return json.load(f)

    # ------------------------------------------------------------------ api
    def exists(self, name: str) -> bool:
        name = self._resolve(name)
        if name in self.mem:
            return True
        return bool(self.root) and os.path.exists(
            os.path.join(self._path(name), "manifest.json"))

    def put(self, name: str, table: Table) -> dict:
        name = self._resolve(name)
        arrays = {n: np.asarray(c) for n, c in table.columns.items()}
        valid = np.asarray(table.valid)
        # Stored artifacts shrink to the live row count (next power of 2):
        # this is what makes reusing a selective Filter/Project output
        # cheaper than recomputing it (paper Figs 16/17) — a stored HDFS
        # file is only as big as its rows.  Host-side, so the dynamic
        # shape never touches XLA.
        nvalid = int(valid.sum())
        if valid[:nvalid].all():            # compacted (Store compacts)
            cap = max(8, 1 << (max(nvalid, 1) - 1).bit_length())
            if cap < len(valid):
                arrays = {n: a[:cap] for n, a in arrays.items()}
                valid = valid[:cap]
        nbytes = int(sum(a.nbytes for a in arrays.values()) + valid.nbytes)
        meta = dict(name=name, capacity=table.capacity,
                    rows=int(valid.sum()), nbytes=nbytes, created=time.time())
        if self.root:
            final = self._path(name)
            tmp = tempfile.mkdtemp(dir=self.root)
            try:
                np.savez(os.path.join(tmp, "data.npz"),
                         __valid__=valid, **arrays)
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump(meta, f)
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)        # atomic publish
            except Exception:
                shutil.rmtree(tmp, ignore_errors=True)
                raise
        else:
            self.mem[name] = table
        self.meta[name] = meta
        return meta

    def get(self, name: str) -> Table:
        name = self._resolve(name)
        if name in self.mem:
            return self.mem[name]
        if not self.root:
            raise KeyError(name)
        z = np.load(os.path.join(self._path(name), "data.npz"))
        valid = z["__valid__"]
        cols = {n: z[n] for n in z.files if n != "__valid__"}
        import jax.numpy as jnp
        return Table({n: jnp.asarray(a) for n, a in cols.items()},
                     jnp.asarray(valid))

    def delete(self, name: str):
        self.mem.pop(name, None)
        self.meta.pop(name, None)
        if self.root:
            p = self._path(name)
            if os.path.exists(p):
                shutil.rmtree(p)

    def nbytes(self, name: str) -> int:
        return self.meta[self._resolve(name)]["nbytes"]

    def total_bytes(self) -> int:
        return sum(m["nbytes"] for m in self.meta.values())

    def names(self):
        return sorted(self.meta)


class Catalog:
    """Source-dataset catalog with version stamps (eviction rule R4:
    modifying a dataset bumps its version, so old fingerprints never match
    and dependent artifacts are invalidated)."""

    def __init__(self, store: ArtifactStore):
        self.store = store
        self.versions: Dict[str, int] = {}
        self.sources: Dict[str, Table] = {}

    def register(self, name: str, table: Table):
        self.versions[name] = self.versions.get(name, -1) + 1
        self.sources[name] = table

    def version(self, name: str) -> int:
        return self.versions.get(name, 0)

    def get(self, name: str) -> Table:
        if name in self.sources:
            return self.sources[name]
        return self.store.get(name)

    def has(self, name: str) -> bool:
        return name in self.sources or self.store.exists(name)
