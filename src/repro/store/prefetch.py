"""Speculative artifact prefetch (DESIGN.md §15).

The stream drivers replay zipfian multi-tenant workloads: a few hot
templates dominate every tenant's traffic, and dataset appends arrive
on a fixed cadence.  Both regularities are visible in the store's own
``read_log`` — the prefetcher mines it, no workload schema required:

  * **popularity** — an exponentially-weighted count per artifact name.
    Zipfian traffic makes the top-k of this EWMA a high-precision
    predictor of the next probe's loads; decay keeps it honest across
    popularity drift (a formerly-hot artifact fades in a handful of
    observations).
  * **append cadence** — the driver notifies ``observe_append`` when a
    source dataset grows.  The prefetcher immediately (a) asks its
    ``maintainer`` callback to delta-refresh the predicted-hot
    artifacts *ahead of the next probe* (the refresh that would
    otherwise run inside the probe's timed window), and (b) re-warms
    them, since refresh rewrites bytes.

Warming is a pure cache fill through ``ArtifactStore.prewarm``: the
authoritative tier never moves, remote-resident predictions ride ONE
batched fetch, and a wrong prediction costs only evictable cache bytes.
Accuracy is accounted: a predicted name actually probed before its
warm entry ages out counts as a hit; ``hit_rate`` is what the tier
benchmark and the service stats report.
"""
from __future__ import annotations

import threading
from typing import Callable, Dict, List, Optional

__all__ = ["SpeculativePrefetcher"]


class SpeculativePrefetcher:
    """Mines an ``ArtifactStore.read_log`` for recurrence and warms the
    predicted-next artifacts.  Thread-safe: the service runs it on a
    background cadence beside the maintenance loop."""

    def __init__(self, store, k: int = 4, decay: float = 0.85,
                 maintainer: Optional[Callable[[set], dict]] = None):
        self.store = store
        self.k = int(k)
        self.decay = float(decay)
        # called with the predicted-hot artifact names on each observed
        # append; typically ``lambda names: rs.maintain(only=names)`` —
        # the ahead-of-arrival delta refresh
        self.maintainer = maintainer
        self._lock = threading.Lock()
        self._score: Dict[str, float] = {}
        self._warmed: set = set()
        self.hits = 0            # predicted AND subsequently probed
        self.observed = 0        # read_log records consumed
        self.appends = 0         # append notifications
        self.prefetched = 0      # names actually warmed
        self.refreshed_ahead = 0  # entries delta-refreshed pre-arrival
        self._events_seen = 0    # poll count, for cadence tracking
        self._last_append_at = None
        self.append_gap = None   # EWMA of polls between appends

    # ------------------------------------------------------------ signals
    def poll(self) -> int:
        """Drain the store's read log into the popularity EWMA.  Also
        settles prediction accuracy: a read of a warmed name is a hit."""
        n = 0
        while True:
            try:
                name, _tier = self.store.read_log.popleft()
            except IndexError:
                break
            n += 1
            with self._lock:
                if name in self._warmed:
                    self.hits += 1
                    self._warmed.discard(name)
                for k in self._score:
                    self._score[k] *= self.decay
                self._score[name] = self._score.get(name, 0.0) + 1.0
        with self._lock:
            self.observed += n
            self._events_seen += 1
        return n

    def observe_append(self, dataset: str = "") -> dict:
        """A source dataset grew: refresh the predicted-hot artifacts
        before the next probe arrives, then re-warm them (refresh moves
        bytes out from under any cached copy).  Returns the maintainer's
        report (empty dict when no maintainer is wired)."""
        self.poll()
        with self._lock:
            self.appends += 1
            if self._last_append_at is not None:
                gap = self._events_seen - self._last_append_at
                self.append_gap = (gap if self.append_gap is None
                                   else 0.5 * self.append_gap + 0.5 * gap)
            self._last_append_at = self._events_seen
        report: dict = {}
        hot = set(self.predict())
        if self.maintainer is not None and hot:
            try:
                report = self.maintainer(hot) or {}
            except Exception:
                report = {}
            self.refreshed_ahead += int(report.get("refreshed", 0))
        self.prefetch()
        return report

    # -------------------------------------------------------- predictions
    def _predict_locked(self) -> List[str]:
        ranked = sorted(self._score.items(),
                        key=lambda kv: (-kv[1], kv[0]))
        return [name for name, s in ranked[:self.k] if s > 0.0]

    def predict(self) -> List[str]:
        """Top-k artifact names by popularity score."""
        with self._lock:
            return self._predict_locked()

    def prefetch(self) -> List[str]:
        """Warm the current predictions into the device/host caches
        (batched remote fetch for cold ones).  Returns the names newly
        warmed this call."""
        self.poll()
        names = self.predict()
        if not names:
            return []
        warmed = self.store.prewarm(names)
        with self._lock:
            self.prefetched += len(warmed)
            self._warmed.update(names)
        return warmed

    # -------------------------------------------------------------- stats
    @property
    def hit_rate(self) -> float:
        denom = self.hits + len(self._warmed)
        return self.hits / denom if denom else 0.0

    def stats(self) -> dict:
        with self._lock:
            return {"hits": self.hits, "observed": self.observed,
                    "appends": self.appends, "prefetched": self.prefetched,
                    "refreshed_ahead": self.refreshed_ahead,
                    "outstanding": len(self._warmed),
                    "append_gap": self.append_gap,
                    "hit_rate": self.hit_rate,
                    "predictions": self._predict_locked()}
