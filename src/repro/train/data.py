"""LM data pipeline built ON the dataflow engine — ReStore's first-class
integration into the training framework (DESIGN.md §4).

Corpus preparation (tokenize-stub -> quality/length filter -> dedup ->
select token columns) is expressed as a physical plan and executed through
the ReStore driver, so repeated training runs that share pipeline prefixes
reuse each other's intermediate artifacts exactly like PigMix queries do.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from ..core import plan as P
from ..core.restore import ReStore
from ..dataflow.expr import Col
from ..dataflow.table import Table


def synthetic_corpus(n_docs: int, seq_len: int, vocab: int,
                     seed: int = 0, capacity: int | None = None) -> Table:
    """Documents with token rows, length and quality columns.  Duplicate
    documents are injected so the dedup stage has work to do."""
    rng = np.random.default_rng(seed)
    toks = rng.integers(1, vocab, (n_docs, seq_len)).astype(np.int32)
    n_dup = max(1, n_docs // 10)
    toks[-n_dup:] = toks[:n_dup]                 # 10% exact duplicates
    return Table.from_numpy({
        "doc_id": np.arange(n_docs, dtype=np.int32),
        "tokens": toks,
        "length": rng.integers(seq_len // 4, seq_len, n_docs)
        .astype(np.int32),
        "quality": rng.uniform(0, 1, n_docs).astype(np.float32),
    }, capacity=capacity or n_docs)


def pipeline_plan(min_quality: float = 0.3, min_length: int = 0,
                  out_name: str = "train_corpus") -> P.PhysicalPlan:
    """tokenize-stub -> quality filter [-> length filter] -> dedup.

    Filters are CHAINED (not fused into one predicate) so pipelines that
    differ only in later stages share the earlier filter sub-jobs — the
    reuse-opportunity structure of paper §2.1."""
    src = P.load("corpus")
    filt = P.filter_(src, Col("quality") > min_quality)
    if min_length:
        filt = P.filter_(filt, Col("length") > min_length)
    proj = P.project(filt, ["tokens", "doc_id"])
    dedup = P.distinct(P.project(proj, ["tokens"]))
    return P.PhysicalPlan([P.store(dedup, out_name)])


def run_pipeline(restore: ReStore, corpus: Table, *, min_quality=0.3,
                 min_length=0, out_name="train_corpus"):
    restore.catalog.register("corpus", corpus) \
        if "corpus" not in restore.catalog.sources else None
    results, report = restore.run_plan(
        pipeline_plan(min_quality, min_length, out_name))
    return results[out_name], report


def batches_from_table(table: Table, batch_size: int, seq_len: int,
                       seed: int = 0):
    """Thin host-side batcher over a pipeline artifact: yields
    (tokens, labels) numpy batches forever (deterministic order, so a
    restarted trainer can skip ahead)."""
    toks = table.to_numpy()["tokens"]
    n = len(toks)
    rng = np.random.default_rng(seed)
    order = rng.permutation(n)
    i = 0
    while True:
        idx = [order[(i + j) % n] for j in range(batch_size)]
        i += batch_size
        chunk = toks[idx][:, :seq_len + 1]
        if chunk.shape[1] < seq_len + 1:
            chunk = np.pad(chunk, ((0, 0), (0, seq_len + 1 - chunk.shape[1])))
        yield chunk[:, :-1].astype(np.int32), chunk[:, 1:].astype(np.int32)
