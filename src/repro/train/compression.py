"""Compression machinery, two families:

  * **Lossy gradient compression** for the data-parallel all-reduce:
    int8 quantization with error feedback.  At 1000+ nodes the DP
    gradient all-reduce is the dominant inter-pod collective (the pod
    axis rides DCI, ~10x slower than ICI).  int8 quantization cuts it
    4x vs f32 / 2x vs bf16; error feedback (the quantization residual
    is carried and added to the next step's gradient) restores
    convergence — the 1-bit-Adam / PowerSGD family of results.
    ``compressed_psum`` is the primitive (usable inside any shard_map
    over the DP axes); ``make_compressed_sync`` wraps a gradient
    pytree.

  * **Lossless columnar compression** for cold artifact tiers
    (DESIGN.md §15): ``encode_array``/``decode_array`` round-trip a
    numpy array bit-exactly through byte-shuffle + zlib.  Grouping
    bytes by significance before deflate is the classic columnar trick
    (Blosc/Parquet): the high bytes of monotone ids and the exponent
    bytes of clustered floats are near-constant runs.  The artifact
    store uses this for the remote object tier, where bandwidth is the
    scarce resource — quantization is NOT an option there, because
    promote→demote→promote round-trips are gated bit-identical.
"""
from __future__ import annotations

import struct
import zlib
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# -------------------------------------------------- lossless columnar
# wire header: magic, zlib level byte, itemsize, ndim, dtype-str length
_COL_MAGIC = b"RCL1"


def encode_array(a: "np.ndarray", level: int = 1) -> bytes:
    """Losslessly encode one column: byte-shuffle + zlib.

    The shuffle transposes the (rows, itemsize) byte matrix so all
    most-significant bytes are contiguous; for typical relational
    columns (small ints in wide dtypes, clustered floats) that turns
    high-entropy interleaving into long near-constant runs.  ``level``
    1 is the speed/ratio sweet spot for a storage tier whose reads are
    latency-dominated anyway."""
    a = np.ascontiguousarray(a)
    dt = a.dtype.str.encode()           # endianness-explicit, e.g. b"<i8"
    raw = a.tobytes()
    if a.dtype.itemsize > 1 and a.size:
        raw = (np.frombuffer(raw, np.uint8)
               .reshape(-1, a.dtype.itemsize).T.tobytes())
    payload = zlib.compress(raw, level)
    header = struct.pack("<4sBBB", _COL_MAGIC, level, a.dtype.itemsize,
                         a.ndim)
    header += struct.pack("<B", len(dt)) + dt
    header += struct.pack(f"<{a.ndim}q", *a.shape)
    return header + payload


def decode_array(buf: bytes) -> "np.ndarray":
    """Inverse of ``encode_array`` — bit-exact round-trip."""
    magic, _level, itemsize, ndim = struct.unpack_from("<4sBBB", buf, 0)
    if magic != _COL_MAGIC:
        raise ValueError("encode_array: bad magic")
    off = 7
    (dtlen,) = struct.unpack_from("<B", buf, off)
    off += 1
    dt = np.dtype(buf[off:off + dtlen].decode())
    off += dtlen
    shape = struct.unpack_from(f"<{ndim}q", buf, off)
    off += 8 * ndim
    raw = zlib.decompress(buf[off:])
    if itemsize > 1 and raw:
        raw = (np.frombuffer(raw, np.uint8)
               .reshape(itemsize, -1).T.tobytes())
    return np.frombuffer(raw, dt).reshape(shape).copy()


def pack_columns(arrays: dict, level: int = 1) -> dict:
    """Encode a {name: array} mapping column-by-column.  Returns
    {name: encoded bytes} — callers (the remote artifact tier) lay the
    blobs out themselves so fetch can be batched."""
    return {n: encode_array(a, level) for n, a in arrays.items()}


def unpack_columns(blobs: dict) -> dict:
    return {n: decode_array(b) for n, b in blobs.items()}


# ----------------------------------------------- lossy gradient path
def quantize_int8(g: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum(g: jnp.ndarray, axis,
                    error: Optional[jnp.ndarray] = None
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Mean of per-shard gradients over ``axis``, exchanged in int8.

    Must run inside a shard_map with ``axis`` bound.  Returns
    (mean_gradient f32, new_error) — feed ``new_error`` back in on the
    next step (error feedback)."""
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis)
    gf = g.astype(jnp.float32)
    if error is not None:
        gf = gf + error
    # shared scale: the max |g| across shards keeps the int8 grids aligned
    amax = jax.lax.pmax(jnp.max(jnp.abs(gf)), axis)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = quantize_int8(gf, scale)
    new_error = gf - dequantize(q, scale)
    total = jax.lax.psum(q.astype(jnp.int32), axis)
    return dequantize(total, scale) / n, new_error


def make_compressed_sync(mesh, dp_axes=("data",)):
    """Returns sync(per_shard_grads, error_tree) -> (mean_grads,
    error_tree): a jit-able pytree wrapper around compressed_psum.

    per_shard_grads leaves carry a leading DP dim (one slice per shard);
    outputs are replicated means."""
    from jax.sharding import PartitionSpec as P

    from ..launch.mesh import shard_map

    axis = dp_axes[0] if len(dp_axes) == 1 else dp_axes

    def sync(grads, errors):
        def body(g_tree, e_tree):
            out = jax.tree_util.tree_map(
                lambda g, e: compressed_psum(g[0], axis, e),
                g_tree, e_tree)
            means = jax.tree_util.tree_map(lambda x: x[0], out,
                                           is_leaf=lambda x:
                                           isinstance(x, tuple))
            errs = jax.tree_util.tree_map(lambda x: x[1], out,
                                          is_leaf=lambda x:
                                          isinstance(x, tuple))
            return means, errs

        in_g = jax.tree_util.tree_map(lambda _: P(axis), grads)
        rep = jax.tree_util.tree_map(lambda _: P(), errors)
        return shard_map(body, mesh=mesh,
                         in_specs=(in_g, rep),
                         out_specs=(rep, rep))(grads, errors)

    return sync
