"""Gradient compression for the data-parallel all-reduce: int8
quantization with error feedback.

At 1000+ nodes the DP gradient all-reduce is the dominant inter-pod
collective (the pod axis rides DCI, ~10x slower than ICI).  int8
quantization cuts it 4x vs f32 / 2x vs bf16; error feedback (the
quantization residual is carried and added to the next step's gradient)
restores convergence — the 1-bit-Adam / PowerSGD family of results.

``compressed_psum`` is the primitive (usable inside any shard_map over
the DP axes); ``make_compressed_sync`` wraps a gradient pytree.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(g: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum(g: jnp.ndarray, axis,
                    error: Optional[jnp.ndarray] = None
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Mean of per-shard gradients over ``axis``, exchanged in int8.

    Must run inside a shard_map with ``axis`` bound.  Returns
    (mean_gradient f32, new_error) — feed ``new_error`` back in on the
    next step (error feedback)."""
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis)
    gf = g.astype(jnp.float32)
    if error is not None:
        gf = gf + error
    # shared scale: the max |g| across shards keeps the int8 grids aligned
    amax = jax.lax.pmax(jnp.max(jnp.abs(gf)), axis)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = quantize_int8(gf, scale)
    new_error = gf - dequantize(q, scale)
    total = jax.lax.psum(q.astype(jnp.int32), axis)
    return dequantize(total, scale) / n, new_error


def make_compressed_sync(mesh, dp_axes=("data",)):
    """Returns sync(per_shard_grads, error_tree) -> (mean_grads,
    error_tree): a jit-able pytree wrapper around compressed_psum.

    per_shard_grads leaves carry a leading DP dim (one slice per shard);
    outputs are replicated means."""
    from jax.sharding import PartitionSpec as P

    from ..launch.mesh import shard_map

    axis = dp_axes[0] if len(dp_axes) == 1 else dp_axes

    def sync(grads, errors):
        def body(g_tree, e_tree):
            out = jax.tree_util.tree_map(
                lambda g, e: compressed_psum(g[0], axis, e),
                g_tree, e_tree)
            means = jax.tree_util.tree_map(lambda x: x[0], out,
                                           is_leaf=lambda x:
                                           isinstance(x, tuple))
            errs = jax.tree_util.tree_map(lambda x: x[1], out,
                                          is_leaf=lambda x:
                                          isinstance(x, tuple))
            return means, errs

        in_g = jax.tree_util.tree_map(lambda _: P(axis), grads)
        rep = jax.tree_util.tree_map(lambda _: P(), errors)
        return shard_map(body, mesh=mesh,
                         in_specs=(in_g, rep),
                         out_specs=(rep, rep))(grads, errors)

    return sync
