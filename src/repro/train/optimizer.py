"""AdamW with global-norm clipping, built from scratch (no optax).

Optimizer-state dtype is configurable: fp32 by default, bf16 for the
>=235B architectures so single-pod training fits HBM (recorded in
DESIGN.md §6).  States are sharded like their parameters plus a ZeRO-1
extension over the data axes (launch/sharding.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "float32"

    def init(self, params) -> Dict[str, Any]:
        dt = jnp.dtype(self.state_dtype)
        zeros = lambda p: jnp.zeros(p.shape, dt)
        return {"m": jax.tree_util.tree_map(zeros, params),
                "v": jax.tree_util.tree_map(zeros, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(self, grads, state, params) -> Tuple[Any, Dict[str, Any]]:
        step = state["step"] + 1
        # global-norm clip in fp32
        leaves = jax.tree_util.tree_leaves(grads)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in leaves))
        scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9))

        b1, b2 = self.b1, self.b2
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)
        dt = jnp.dtype(self.state_dtype)

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32) * scale
            m32 = m.astype(jnp.float32) * b1 + gf * (1 - b1)
            v32 = v.astype(jnp.float32) * b2 + jnp.square(gf) * (1 - b2)
            u = (m32 / c1) / (jnp.sqrt(v32 / c2) + self.eps)
            u = u + self.weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - self.lr * u
            return new_p.astype(p.dtype), m32.astype(dt), v32.astype(dt)

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        out = [upd(g, m, v, p) for g, m, v, p
               in zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v, "step": step}, gnorm
