"""Fault-tolerant checkpointing.

Mesh-agnostic: leaves are saved as host numpy arrays keyed by tree path,
so a checkpoint written on one mesh restores onto any other (elastic
scaling — the restore path re-device_puts each leaf with the target
sharding).  Writes are atomic: tmp dir + manifest fingerprint + rename;
a crashed writer can never produce a checkpoint that ``latest_step``
would pick up.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
from typing import Any, Dict, Optional

import jax
import numpy as np


def jnp_astype(arr: np.ndarray, dtype):
    """Cast via jnp — handles ml_dtypes (bfloat16) that numpy can't."""
    import jax.numpy as jnp
    return jnp.asarray(arr).astype(dtype)


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind not in "iufb" or arr.dtype.itemsize == 0:
            # npz can't round-trip ml_dtypes (bf16 etc): upcast losslessly
            arr = arr.astype(np.float32)
        elif str(arr.dtype) == "bfloat16":
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def save_checkpoint(ckpt_dir: str, step: int, tree: Any,
                    extra: Optional[dict] = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(tree)
    tmp = tempfile.mkdtemp(dir=ckpt_dir)
    try:
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{k.replace("/", "__"): v for k, v in flat.items()})
        digest = hashlib.sha256()
        for k in sorted(flat):
            digest.update(k.encode())
            digest.update(flat[k].tobytes()[:4096])
        manifest = {"step": step, "keys": sorted(flat),
                    "fingerprint": digest.hexdigest(),
                    "extra": extra or {}}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)       # atomic publish
        return final
    except Exception:
        shutil.rmtree(tmp, ignore_errors=True)
        raise


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and os.path.exists(
                os.path.join(ckpt_dir, d, "manifest.json")):
            try:
                with open(os.path.join(ckpt_dir, d, "manifest.json")) as f:
                    json.load(f)          # torn manifests are skipped
                steps.append(int(d.split("_")[1]))
            except Exception:
                continue
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, target_tree: Any,
                       shardings: Any = None):
    """Restore into the structure of ``target_tree`` (shapes must match);
    ``shardings``, when given, re-shards each leaf for the current mesh
    (elastic restore)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    z = np.load(os.path.join(path, "arrays.npz"))
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    flat_target, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
    shard_flat = (jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda x: hasattr(x, "spec"))
        if shardings is not None else [None] * len(flat_target))
    leaves = []
    for (kpath, leaf), sh in zip(flat_target, shard_flat):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in kpath).replace("/", "__")
        arr = z[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        arr = np.asarray(jnp_astype(arr, leaf.dtype))
        leaves.append(jax.device_put(arr, sh) if sh is not None
                      else jax.device_put(arr))
    return treedef.unflatten(leaves), manifest
