"""Seeded fault injection for the artifact store (DESIGN.md §13).

The store calls ``injector.on(point, name, path=...)`` at its IO
choke points:

  ``read``       top of every disk load attempt;
  ``write``      before an artifact's data files are written;
  ``publish``    after the tmp dir is fully written, before the atomic
                 rename — a crash here leaves an orphaned ``.tmp-*``;
  ``published``  after the rename, with ``path`` = the final dir — a
                 point where the injector may corrupt real bytes.

The remote object tier (DESIGN.md §15) adds three more:

  ``remote_read``       before a remote blob fetch;
  ``remote_write``      before the blob upload of a demotion — a crash
                        here leaves the disk copy authoritative;
  ``remote_published``  after the atomic remote publish, BEFORE the
                        local delete that commits the demotion, with
                        ``path`` = the blob file — a crash here leaves
                        both copies (reopen reconciles to the remote),
                        and corruptions land on the published blob.

A ``FaultSchedule`` decides, from a seed, which calls fault and how.
Determinism is the contract: the same seed produces the same fault
sequence, so every failure found by the sweep replays exactly.

Fault kinds:

  ``crash``      raise SimulatedCrash (process death; at ``publish`` the
                 tmp dir survives like a real kill);
  ``transient``  raise OSError (flaky IO — the retry path absorbs it);
  ``latency``    sleep a few ms (stragglers; surfaces races);
  ``truncate``   cut the tail off one published ``.npz`` (torn write);
  ``flip``       XOR one byte of a published file (bit rot);
  ``manifest``   garble the published ``manifest.json``.

Corruptions only apply at ``published``; raise-kinds apply anywhere
else.  ``max_faults`` bounds the total injected so every schedule
eventually goes quiet and queries terminate.
"""
from __future__ import annotations

import os
import random
import threading
import time
from typing import Dict, Optional

from ..store.artifacts import SimulatedCrash

RAISE_KINDS = ("crash", "transient", "latency")
CORRUPT_KINDS = ("truncate", "flip", "manifest")


class FaultSchedule:
    """Seeded decision source: at each store IO event, draw whether to
    fault and which kind.  ``rates`` maps fault kind -> per-event
    probability; kinds absent from the map never fire."""

    def __init__(self, seed: int, rates: Optional[Dict[str, float]] = None,
                 max_faults: int = 4):
        self.seed = int(seed)
        self.rates = dict(rates if rates is not None else {
            "transient": 0.05, "latency": 0.05,
            "truncate": 0.02, "flip": 0.02, "manifest": 0.01,
        })
        self.max_faults = int(max_faults)
        self._rng = random.Random(self.seed)

    def draw(self, point: str) -> Optional[str]:
        """The fault kind to inject at this event, or None.  The rng is
        advanced exactly once per event regardless of outcome, keeping
        the sequence aligned across store-side code changes."""
        u = self._rng.random()
        acc = 0.0
        for kind, rate in sorted(self.rates.items()):
            acc += rate
            if u < acc:
                return kind
        return None


class FaultInjector:
    """Store-side shim: translates schedule draws into real damage.

    Thread-safe — service workers and the write-behind flusher hit the
    same injector.  Counters record what was actually injected so the
    suites can assert coverage (a sweep that never fired a fault proves
    nothing)."""

    def __init__(self, schedule: FaultSchedule,
                 latency_s: float = 0.003):
        self.schedule = schedule
        self.latency_s = float(latency_s)
        self.injected: Dict[str, int] = {}
        self._lock = threading.Lock()
        # one-shot arming: "crash at the next publish" for the crash
        # harness (deterministic kill point, not a probability draw)
        self._armed: Optional[str] = None

    def arm(self, point: str) -> None:
        """Force a SimulatedCrash at the next event of ``point``."""
        self._armed = point

    def total_injected(self) -> int:
        with self._lock:
            return sum(self.injected.values())

    # ------------------------------------------------------------- hook
    def on(self, point: str, name: str, path: Optional[str] = None):
        if self._armed == point:
            self._armed = None
            with self._lock:
                self.injected["crash"] = self.injected.get("crash", 0) + 1
            raise SimulatedCrash(f"armed crash at {point}({name})")
        with self._lock:
            if sum(self.injected.values()) >= self.schedule.max_faults:
                return
            kind = self.schedule.draw(point)
            if kind is None:
                return
            # a corruption can only land on published bytes (local dir
            # or remote blob); a raise after publish would be attributed
            # to a write that in fact succeeded — both are no-ops,
            # decided (and NOT counted) atomically with the draw so the
            # budget stays exact
            published = point in ("published", "remote_published")
            if kind in CORRUPT_KINDS and (not published or path is None):
                return
            if kind in RAISE_KINDS and published:
                return
            self.injected[kind] = self.injected.get(kind, 0) + 1
        if kind in CORRUPT_KINDS:
            self._corrupt(kind, path)
            return
        if kind == "latency":
            time.sleep(self.latency_s)
            return
        if kind == "crash":
            raise SimulatedCrash(f"injected crash at {point}({name})")
        raise OSError(f"injected transient IO error at {point}({name})")

    # ------------------------------------------------------- corruption
    def _corrupt(self, kind: str, path: str) -> None:
        rng = random.Random(self.schedule.seed ^ 0x5EED)
        if os.path.isfile(path):
            # remote tier: ``path`` is the published blob file itself.
            # "manifest" garbles the JSON header region (first bytes),
            # the others damage the body like their npz counterparts.
            self._corrupt_file(kind, path, rng)
            return
        if kind == "manifest":
            mpath = os.path.join(path, "manifest.json")
            try:
                with open(mpath, "r+b") as f:
                    data = bytearray(f.read())
                    if not data:
                        return
                    i = rng.randrange(len(data))
                    data[i] ^= 0xFF
                    f.seek(0)
                    f.write(bytes(data))
                    f.truncate()
            except OSError:
                pass
            return
        npz = sorted(fn for fn in os.listdir(path) if fn.endswith(".npz"))
        if not npz:
            return
        target = os.path.join(path, rng.choice(npz))
        try:
            size = os.path.getsize(target)
            if size < 2:
                return
            with open(target, "r+b") as f:
                if kind == "truncate":
                    f.truncate(rng.randrange(1, size))
                else:                       # flip one byte
                    i = rng.randrange(size)
                    f.seek(i)
                    b = f.read(1)
                    f.seek(i)
                    f.write(bytes([b[0] ^ 0xFF]))
        except OSError:
            pass

    @staticmethod
    def _corrupt_file(kind: str, path: str, rng: random.Random) -> None:
        try:
            size = os.path.getsize(path)
            if size < 16:
                return
            with open(path, "r+b") as f:
                if kind == "truncate":
                    f.truncate(rng.randrange(1, size))
                elif kind == "manifest":
                    # damage the self-describing header: any byte in the
                    # first 64 makes the JSON (or magic) unreadable
                    i = rng.randrange(min(64, size))
                    f.seek(i)
                    b = f.read(1)
                    f.seek(i)
                    f.write(bytes([b[0] ^ 0xFF]))
                else:                       # flip one byte anywhere
                    i = rng.randrange(size)
                    f.seek(i)
                    b = f.read(1)
                    f.seek(i)
                    f.write(bytes([b[0] ^ 0xFF]))
        except OSError:
            pass
