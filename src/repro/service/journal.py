"""Durable repository journal (WAL-style, DESIGN.md §13).

Repository state — which artifacts exist, what produced them, their use
statistics — must survive process death: the paper's premise is reuse
across workflows submitted over days.  The journal lives beside the
artifacts it describes::

    <store_root>/_journal/snapshot.json    periodic full state (atomic)
    <store_root>/_journal/journal.jsonl    one JSON record per mutation

(``_journal`` fails the store's round-trip name check, so a store scan
never mistakes it for an artifact.)  Every repository mutation appends
one line BEFORE the mutating call returns; ``rotate`` compacts — atomic
snapshot write, then atomic journal truncate, in that order, so a crash
between the two merely replays records the snapshot already contains
(every record is idempotent: ``use`` carries post-update totals, ``add``
is keyed by signature).

Recovery (``RepositoryJournal.recover``) rebuilds state from snapshot +
journal, tolerating a corrupt/missing snapshot (the journal is the
source of truth) and a torn final journal line (a crash mid-append).
It then **reconciles against reality**: entries whose artifacts are
missing from disk or fail checksum verification are dropped, and
orphaned ``.tmp-*`` publish dirs are reaped — the recovered repository
never advertises bytes that don't exist.  Pins are run-scoped (their
owning workflows died with the process) and pending refreshes are
re-derived by the next ``maintain`` sweep, so neither is restored live.
"""
from __future__ import annotations

import json
import os
import tempfile
import threading
import time
from typing import Dict, List, Optional

JOURNAL_DIRNAME = "_journal"
DEFAULT_ROTATE_EVERY = 4096


class RepositoryJournal:
    """Append-only mutation log for one Repository.

    Bind with ``repo.bind_journal(journal)`` (and ``journal.repo =
    repo`` for auto-rotation); the repository then logs every add /
    use / drop / refresh / pin / unpin / pending transition."""

    def __init__(self, root: str,
                 rotate_every: int = DEFAULT_ROTATE_EVERY):
        self.dir = os.path.join(root, JOURNAL_DIRNAME)
        os.makedirs(self.dir, exist_ok=True)
        self.journal_path = os.path.join(self.dir, "journal.jsonl")
        self.snapshot_path = os.path.join(self.dir, "snapshot.json")
        self.rotate_every = int(rotate_every)
        self.repo = None                # bound for auto-rotation
        self._lock = threading.Lock()
        self._fh = open(self.journal_path, "a")
        self._n_since_rotate = self._count_lines()
        self.appended = 0
        self.rotations = 0

    def _count_lines(self) -> int:
        try:
            with open(self.journal_path) as f:
                return sum(1 for _ in f)
        except OSError:
            return 0

    # ---------------------------------------------------------- appends
    def _append(self, rec: dict) -> None:
        rec["ts"] = time.time()
        line = json.dumps(rec, separators=(",", ":"))
        with self._lock:
            self._fh.write(line + "\n")
            self._fh.flush()            # to the OS: survives SIGKILL
            self._n_since_rotate += 1
            self.appended += 1
            due = (self.repo is not None
                   and self._n_since_rotate >= self.rotate_every)
        if due:
            self.rotate(self.repo)

    def record_add(self, entry) -> None:
        from ..core.serialize import entry_to_json
        self._append({"t": "add", "e": entry_to_json(entry)})

    def record_use(self, entry, saved_s: float, kind: str) -> None:
        # post-update totals, not deltas: replay is idempotent even if
        # a crash lands between the append and the in-memory update
        self._append({"t": "use", "sig": entry.signature,
                      "last_used": entry.last_used,
                      "use_count": entry.use_count,
                      "semantic_uses": entry.semantic_uses,
                      "saved_s_total": entry.saved_s_total,
                      "kind": kind, "saved_s": saved_s})

    def record_drop(self, signatures: List[str]) -> None:
        self._append({"t": "drop", "sigs": list(signatures)})

    def record_refresh(self, old_sig: str, entry) -> None:
        from ..core.serialize import entry_to_json
        self._append({"t": "refresh", "old": old_sig,
                      "e": entry_to_json(entry)})

    def record_pin(self, artifacts) -> None:
        self._append({"t": "pin", "arts": sorted(artifacts)})

    def record_unpin(self, artifacts) -> None:
        self._append({"t": "unpin", "arts": sorted(artifacts)})

    def record_pending(self, signature: str) -> None:
        self._append({"t": "pending", "sig": signature})

    # --------------------------------------------------------- rotation
    def rotate(self, repo) -> None:
        """Compact: atomically snapshot full state, then atomically
        truncate the journal.  Crash-ordering safe — see module doc."""
        from ..core.serialize import repository_to_json
        with repo._lock:
            payload = repository_to_json(repo)
        with self._lock:
            fd, tmp = tempfile.mkstemp(dir=self.dir)
            with os.fdopen(fd, "w") as f:
                f.write(payload)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, self.snapshot_path)
            # truncate via rename of an empty file: a reader (or a
            # crash) never sees a half-truncated journal
            self._fh.close()
            fd, tmp = tempfile.mkstemp(dir=self.dir)
            os.close(fd)
            os.replace(tmp, self.journal_path)
            self._fh = open(self.journal_path, "a")
            self._n_since_rotate = 0
            self.rotations += 1

    def close(self) -> None:
        with self._lock:
            self._fh.close()

    # --------------------------------------------------------- recovery
    @classmethod
    def recover(cls, store, repository=None,
                rotate_every: int = DEFAULT_ROTATE_EVERY,
                tmp_gc: bool = True):
        """Rebuild repository state from the journal beside ``store``'s
        root, reconcile against the artifacts actually on disk, and
        return ``(repository, journal)`` with the journal bound and
        freshly rotated.  ``repository`` supplies policy/budget config
        (a default Repository otherwise); its entry list is replaced."""
        from ..core.repository import Repository
        repo = repository if repository is not None else Repository()
        root = store.root
        if root is None:
            raise ValueError("recover() needs an on-disk store")
        journal = cls(root, rotate_every=rotate_every)
        entries = _replay_dir(journal.dir)
        # reconcile: every surviving entry must point at verified bytes
        dropped = 0
        kept = []
        for e in entries.values():
            if store.exists(e.artifact) and store.verify(e.artifact):
                kept.append(e)
            else:
                store.quarantine(e.artifact)
                dropped += 1
        with repo._lock:
            repo.entries = kept
            repo.by_sig = {e.signature: e for e in kept}
            repo.pinned = {}
            repo.pending_refresh = {}
            repo._ordered_dirty = True
            repo.bind_store(store)
            repo.rebalance()            # budget applies to survivors too
        if tmp_gc:
            store.gc_tmp(0)             # no writer survived the crash
        repo.bind_journal(journal)
        journal.repo = repo
        journal.rotate(repo)            # recovered state becomes snapshot
        journal.recovered_entries = len(kept)
        journal.reconciled_drops = dropped
        return repo, journal


# ---------------------------------------------------------------- replay
def _iter_records(path: str):
    """Yield parsed journal records, stopping at the first torn line
    (a crash mid-append tears only the tail)."""
    try:
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    yield json.loads(line)
                except ValueError:
                    return              # torn tail: everything after is gone
    except OSError:
        return


def _replay_dir(journal_dir: str) -> Dict[str, object]:
    """Entries-by-signature from snapshot + journal in ``journal_dir``.
    A corrupt snapshot is skipped (the journal since the last rotation
    still holds every live mutation... of entries added since; older
    state is lost only if BOTH files are damaged)."""
    from ..core.serialize import entry_from_json
    entries: Dict[str, object] = {}
    snap = os.path.join(journal_dir, "snapshot.json")
    try:
        with open(snap) as f:
            data = json.load(f)
        for d in data.get("entries", []):
            try:
                e = entry_from_json(d)
            except (KeyError, TypeError, ValueError):
                continue
            if e is not None:
                entries[e.signature] = e
    except (OSError, ValueError):
        pass                            # journal replay is the fallback
    for rec in _iter_records(os.path.join(journal_dir, "journal.jsonl")):
        t = rec.get("t")
        try:
            if t == "add" or t == "refresh":
                e = entry_from_json(rec["e"])
                if e is not None:
                    if t == "refresh":
                        entries.pop(rec.get("old"), None)
                    entries[e.signature] = e
            elif t == "use":
                e = entries.get(rec["sig"])
                if e is not None:
                    e.last_used = rec["last_used"]
                    e.use_count = rec["use_count"]
                    e.semantic_uses = rec.get("semantic_uses",
                                              e.semantic_uses)
                    e.saved_s_total = rec.get("saved_s_total",
                                              e.saved_s_total)
            elif t == "drop":
                for sig in rec.get("sigs", []):
                    entries.pop(sig, None)
            # pin/unpin/pending: run-scoped, not restored (module doc)
        except (KeyError, TypeError, ValueError):
            continue                    # one bad record never kills replay
    return entries


def replay_journal(path: str, repo=None):
    """Standalone replay for ``serialize.load_repository``'s corrupt-
    state fallback.  ``path`` is a journal directory (or a store root
    containing one).  Entries are installed via ``repo.add`` so the
    caller's keep-rules/budget apply."""
    from ..core.repository import Repository
    repo = repo if repo is not None else Repository()
    d = path
    if os.path.basename(d) != JOURNAL_DIRNAME:
        cand = os.path.join(d, JOURNAL_DIRNAME)
        if os.path.isdir(cand):
            d = cand
    for e in _replay_dir(d).values():
        repo.add(e)
    return repo


def journal_dir(store_root: str) -> str:
    return os.path.join(store_root, JOURNAL_DIRNAME)


def has_journal(store_root: Optional[str]) -> bool:
    return bool(store_root) and os.path.isdir(
        os.path.join(store_root, JOURNAL_DIRNAME))
