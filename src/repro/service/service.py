"""Concurrent multi-tenant ReStore service (DESIGN.md §13).

``ReStoreService`` turns the single-query driver into a long-running
server: N worker threads execute whole workflows concurrently over ONE
shared catalog / artifact store / repository / jit cache, which is the
whole point — tenants reuse each other's sub-job results the moment
they are registered.

Scheduling and robustness:

  * **admission queue** — bounded; ``submit`` blocks (backpressure) or
    raises ``ServiceOverloaded`` when full;
  * **per-tenant fairness** — one FIFO per tenant, drained round-robin,
    with an optional per-tenant in-flight cap, so one chatty tenant
    cannot starve the rest of the worker pool (and thereby of the
    repository byte budget its artifacts compete for);
  * **singleflight** — tickets are keyed by the workflow plan's
    structural fingerprint; a submit matching a queued or executing key
    attaches to the leader and receives its results.  Two tenants
    submitting the same job at the same instant compute it once — the
    stampede that bursty recurrent arrivals (Chen et al.) make common;
  * **retries / timeouts** — transient store errors requeue the ticket
    with capped exponential backoff up to ``max_attempts``; a ticket
    older than its ``deadline_s`` when a worker picks it up fails with
    ``ServiceTimeout`` (requeue-or-fail);
  * **degradation** — corrupt/missing artifacts are quarantined inside
    the driver (ArtifactError -> cold recompute); the per-run counts
    surface in ``stats()["degraded"]``.
"""
from __future__ import annotations

import collections
import threading
import time
from typing import Dict, List, Optional

from ..core.mqo import optimize_batch
from ..core.plan import PhysicalPlan, plan_signature
from ..core.repository import Repository
from ..core.restore import ReStore
from ..dataflow.builder import as_plan
from ..store.artifacts import ArtifactError, Catalog, TransientStoreError


class ServiceOverloaded(RuntimeError):
    """Admission queue full and the caller declined to wait."""


class ServiceTimeout(RuntimeError):
    """The ticket exceeded its deadline before a worker could run it."""


class ServiceClosed(RuntimeError):
    """submit() after stop()."""


class Ticket:
    """Handle for one submitted workflow."""

    def __init__(self, plan: PhysicalPlan, tenant: str, key: str,
                 deadline_s: Optional[float]):
        self.plan = plan
        self.tenant = tenant
        self.key = key
        self.deadline_s = deadline_s
        self.submitted_at = time.time()
        self.attempts = 0
        self.followers: List["Ticket"] = []
        self._ev = threading.Event()
        self._results = None
        self._report = None
        self._error: Optional[BaseException] = None

    def done(self) -> bool:
        return self._ev.is_set()

    def result(self, timeout: Optional[float] = None):
        """Block for the outcome: returns ``(results, report)`` or
        raises the failure (ServiceTimeout, TransientStoreError after
        all retries, ...).  ``timeout`` bounds the wait itself."""
        if not self._ev.wait(timeout):
            raise TimeoutError(
                f"ticket for tenant {self.tenant!r} still pending")
        if self._error is not None:
            raise self._error
        return self._results, self._report

    def _resolve(self, results, report) -> None:
        self._results, self._report = results, report
        self._ev.set()

    def _reject(self, err: BaseException) -> None:
        self._error = err
        self._ev.set()


class ReStoreService:
    def __init__(self, catalog: Catalog, store,
                 repository: Optional[Repository] = None,
                 n_workers: int = 4,
                 max_queue: int = 64,
                 per_tenant_inflight: Optional[int] = None,
                 singleflight: bool = True,
                 max_attempts: int = 3,
                 retry_base_s: float = 0.01,
                 retry_cap_s: float = 0.25,
                 journal=None,
                 maintain_interval_s: Optional[float] = None,
                 prefetch_interval_s: Optional[float] = None,
                 prefetch_k: int = 4,
                 job_overhead_s: float = 0.0,
                 **driver_kwargs):
        self.catalog = catalog
        self.store = store
        self.repo = repository if repository is not None else Repository()
        self.repo.bind_store(store)
        if journal is not None:
            self.repo.bind_journal(journal)
            journal.repo = self.repo
        self.journal = journal
        self.n_workers = int(n_workers)
        self.max_queue = int(max_queue)
        self.per_tenant_inflight = per_tenant_inflight
        self.singleflight = singleflight
        self.max_attempts = int(max_attempts)
        self.retry_base_s = float(retry_base_s)
        self.retry_cap_s = float(retry_cap_s)
        # constant per-job stall modelling the launch + DFS round-trip
        # overhead of the paper's MapReduce setting (our in-process
        # engine has none).  It is WAIT, not compute, so a correctly
        # concurrent pool overlaps it across workers — the service
        # bench's goodput-scaling gate rides on exactly that
        self.job_overhead_s = float(job_overhead_s)
        # one driver per worker: drivers carry per-run state (_run_pins,
        # _art_versions) but share catalog/store/repo/jit-cache, so a
        # sub-job one tenant materializes is immediately matchable by
        # every other worker
        self._drivers = [ReStore(catalog, store, self.repo,
                                 **driver_kwargs)
                         for _ in range(self.n_workers)]
        self._cv = threading.Condition()
        self._queues: "Dict[str, collections.deque]" = {}
        self._rr: "collections.deque[str]" = collections.deque()
        self._qsize = 0
        self._inflight: Dict[str, Ticket] = {}     # singleflight leaders
        self._executing_keys: set = set()
        self._executing_by_tenant: Dict[str, int] = {}
        self._closed = False
        self._stats = {
            "submitted": 0, "completed": 0, "failed": 0, "rejected": 0,
            "retries": 0, "timeouts": 0, "singleflight_hits": 0,
            "dup_executions": 0, "degraded": 0, "flush_failures": 0,
            "batches": 0, "batch_shared_subplans": 0,
        }
        self._tenant_stats: Dict[str, Dict[str, int]] = {}
        self._workers = [
            threading.Thread(target=self._worker_loop, args=(i,),
                             name=f"restore-worker-{i}", daemon=True)
            for i in range(self.n_workers)]
        for t in self._workers:
            t.start()
        self._maintain_stop = threading.Event()
        self._maintain_thread = None
        if maintain_interval_s is not None:
            self._maintain_thread = threading.Thread(
                target=self._maintain_loop, args=(float(maintain_interval_s),),
                name="restore-maintainer", daemon=True)
            self._maintain_thread.start()
        # speculative prefetcher (DESIGN.md §15): mines the store's read
        # log on a background cadence beside the maintenance loop and
        # warms predicted-hot artifacts; its ahead-of-arrival refresh
        # reuses maintain_now restricted to the predicted names
        self.prefetcher = None
        self._prefetch_stop = threading.Event()
        self._prefetch_thread = None
        if prefetch_interval_s is not None:
            from ..store.prefetch import SpeculativePrefetcher
            self.prefetcher = SpeculativePrefetcher(
                store, k=prefetch_k,
                maintainer=lambda names: self.repo.maintain(
                    self.catalog, self._drivers[0].engine, self.store,
                    only=names))
            self._prefetch_thread = threading.Thread(
                target=self._prefetch_loop,
                args=(float(prefetch_interval_s),),
                name="restore-prefetcher", daemon=True)
            self._prefetch_thread.start()

    # ------------------------------------------------------------ submit
    def submit(self, plan, tenant: str = "default",
               block: bool = True, timeout: Optional[float] = None,
               deadline_s: Optional[float] = None) -> Ticket:
        """Enqueue a workflow — a ``PhysicalPlan`` or a Pig-style
        builder (``dataflow.builder.Dataflow``, lowered on entry);
        returns a Ticket immediately.  With the queue full:
        ``block=True`` waits (``timeout`` bounds it) for space, else
        raises ServiceOverloaded."""
        plan = as_plan(plan)
        key = plan_signature(plan)
        deadline = time.time() + timeout if timeout is not None else None
        with self._cv:
            if self._closed:
                raise ServiceClosed("service is stopped")
            self._stats["submitted"] += 1
            self._tenant(tenant)["submitted"] += 1
            if self.singleflight:
                leader = self._inflight.get(key)
                if leader is not None:
                    t = Ticket(plan, tenant, key, deadline_s)
                    leader.followers.append(t)
                    self._stats["singleflight_hits"] += 1
                    self._tenant(tenant)["singleflight_hits"] += 1
                    return t
            while self._qsize >= self.max_queue and not self._closed:
                if not block:
                    self._stats["rejected"] += 1
                    self._tenant(tenant)["rejected"] += 1
                    raise ServiceOverloaded(
                        f"queue full ({self.max_queue} pending)")
                remaining = None if deadline is None \
                    else deadline - time.time()
                if remaining is not None and remaining <= 0:
                    self._stats["rejected"] += 1
                    self._tenant(tenant)["rejected"] += 1
                    raise ServiceOverloaded(
                        f"queue full ({self.max_queue} pending)")
                self._cv.wait(remaining)
            if self._closed:
                raise ServiceClosed("service is stopped")
            t = Ticket(plan, tenant, key, deadline_s)
            self._enqueue_locked(t)
            if self.singleflight:
                self._inflight[key] = t
            self._cv.notify_all()
            return t

    def run(self, plan, tenant: str = "default",
            timeout: Optional[float] = None):
        """Convenience: submit (plan or builder) and wait."""
        return self.submit(plan, tenant).result(timeout)

    def submit_batch(self, queries, tenants=None, tenant: str = "default",
                     semantic: bool = True,
                     timeout: Optional[float] = None) -> List[Ticket]:
        """Drain a batch through the multi-query optimizer (DESIGN.md
        §16) and fan results out to per-query tickets.

        The batch window extends singleflight from identical-plan to
        shared-subplan granularity: ``optimize_batch`` finds sub-plans
        common to several queued queries (exactly or by subsumption),
        the shared prefix is submitted once and awaited, and only then
        are the per-query tickets enqueued — their rewrites splice the
        freshly materialized shared artifacts, so a sub-job consumed by
        five queries executes once no matter which workers pick them up.

        Known-uses hints and pins are installed for the batch's
        lifetime (a background waiter releases them when the last
        ticket settles).  A shared-prefix failure degrades gracefully:
        the queries still run, each recomputing cold.  ``queries`` may
        mix plans and builders; ``tenants`` (optional, same length)
        attributes each ticket, else all go to ``tenant``."""
        plans = [as_plan(q) for q in queries]
        if tenants is None:
            tenants = [tenant] * len(plans)
        if len(tenants) != len(plans):
            raise ValueError("tenants must match queries 1:1")
        bp = optimize_batch(plans, repo=self.repo, semantic=semantic)
        with self._cv:
            self._stats["batches"] += 1
            self._stats["batch_shared_subplans"] += len(bp.shared)
        released = threading.Event()
        self.repo.set_known_uses(bp.known_uses)
        self.repo.pin(bp.boundary_artifacts)

        def _release():
            if released.is_set():
                return
            released.set()
            self.repo.unpin(bp.boundary_artifacts)
            self.repo.clear_known_uses(bp.known_uses)
            self.repo.rebalance()

        try:
            if bp.shared_plan is not None:
                try:
                    self.submit(bp.shared_plan,
                                tenant="_batch").result(timeout)
                except Exception:
                    pass        # degraded: queries recompute cold
            tickets = [self.submit(p, tenant=t)
                       for p, t in zip(plans, tenants)]
        except BaseException:
            _release()
            raise

        def _waiter():
            for t in tickets:
                t._ev.wait()
            _release()

        threading.Thread(target=_waiter, name="restore-batch-waiter",
                         daemon=True).start()
        return tickets

    def _tenant(self, tenant: str) -> Dict[str, int]:
        st = self._tenant_stats.get(tenant)
        if st is None:
            st = self._tenant_stats[tenant] = {
                "submitted": 0, "completed": 0, "failed": 0,
                "rejected": 0, "singleflight_hits": 0}
        return st

    def _enqueue_locked(self, t: Ticket) -> None:
        q = self._queues.get(t.tenant)
        if q is None:
            q = self._queues[t.tenant] = collections.deque()
            self._rr.append(t.tenant)
        q.append(t)
        self._qsize += 1

    # ----------------------------------------------------------- workers
    def _next_ticket_locked(self) -> Optional[Ticket]:
        """Round-robin over tenants with queued work, honouring the
        per-tenant in-flight cap.  Advances the rotation so service
        order interleaves tenants regardless of queue depths."""
        for _ in range(len(self._rr)):
            tenant = self._rr[0]
            self._rr.rotate(-1)
            q = self._queues.get(tenant)
            if not q:
                continue
            if (self.per_tenant_inflight is not None
                    and self._executing_by_tenant.get(tenant, 0)
                    >= self.per_tenant_inflight):
                continue
            t = q.popleft()
            self._qsize -= 1
            return t
        return None

    def _worker_loop(self, idx: int) -> None:
        driver = self._drivers[idx]
        while True:
            with self._cv:
                t = self._next_ticket_locked()
                while t is None and not self._closed:
                    self._cv.wait()
                    t = self._next_ticket_locked()
                if t is None:           # closed and drained
                    return
                now = time.time()
                if (t.deadline_s is not None
                        and now - t.submitted_at > t.deadline_s):
                    self._stats["timeouts"] += 1
                    self._finish_locked(
                        t, error=ServiceTimeout(
                            f"queued {now - t.submitted_at:.3f}s > "
                            f"deadline {t.deadline_s:.3f}s"))
                    self._cv.notify_all()
                    continue
                if t.key in self._executing_keys:
                    # the invariant the singleflight gate exists for;
                    # asserted == 0 by the bench gate
                    self._stats["dup_executions"] += 1
                self._executing_keys.add(t.key)
                self._executing_by_tenant[t.tenant] = \
                    self._executing_by_tenant.get(t.tenant, 0) + 1
                self._cv.notify_all()
            t.attempts += 1
            try:
                if self.job_overhead_s > 0:
                    time.sleep(self.job_overhead_s)
                results, report = driver.run_plan(t.plan)
            except TransientStoreError as e:
                if t.attempts < self.max_attempts:
                    with self._cv:
                        self._stats["retries"] += 1
                    # the ticket stays "executing" through the backoff so
                    # stop(drain=True) cannot slip past it mid-retry
                    time.sleep(min(self.retry_cap_s,
                                   self.retry_base_s
                                   * (2 ** (t.attempts - 1))))
                    with self._cv:
                        self._after_exec_locked(t)
                        self._enqueue_locked(t)
                        self._cv.notify_all()
                else:
                    with self._cv:
                        self._after_exec_locked(t)
                        self._finish_locked(t, error=e)
                        self._cv.notify_all()
            except BaseException as e:
                with self._cv:
                    self._after_exec_locked(t)
                    self._finish_locked(t, error=e)
                    self._cv.notify_all()
            else:
                with self._cv:
                    self._after_exec_locked(t)
                    self._stats["degraded"] += report.degraded
                    self._stats["flush_failures"] += \
                        len(report.flush_failures)
                    self._finish_locked(t, results=results, report=report)
                    self._cv.notify_all()

    def _after_exec_locked(self, t: Ticket) -> None:
        self._executing_keys.discard(t.key)
        n = self._executing_by_tenant.get(t.tenant, 1) - 1
        if n > 0:
            self._executing_by_tenant[t.tenant] = n
        else:
            self._executing_by_tenant.pop(t.tenant, None)

    def _finish_locked(self, t: Ticket, results=None, report=None,
                       error: Optional[BaseException] = None) -> None:
        """Resolve a ticket (and its singleflight followers) and retire
        its key.  Callers hold the service lock."""
        if self._inflight.get(t.key) is t:
            del self._inflight[t.key]
        tickets = [t] + t.followers
        for tk in tickets:
            if error is not None:
                self._stats["failed"] += 1
                self._tenant(tk.tenant)["failed"] += 1
                tk._reject(error)
            else:
                self._stats["completed"] += 1
                self._tenant(tk.tenant)["completed"] += 1
                tk._resolve(results, report)
        t.followers = []

    # ------------------------------------------------------- maintenance
    def _maintain_loop(self, interval_s: float) -> None:
        while not self._maintain_stop.wait(interval_s):
            try:
                self.maintain_now()
            except Exception:
                pass                    # background sweep must not die

    def maintain_now(self, mode: str = "auto") -> Dict[str, int]:
        """One incremental-maintenance sweep through worker 0's engine
        (thread-safe against in-flight queries: the repository and store
        serialize their own mutations)."""
        return self.repo.maintain(self.catalog, self._drivers[0].engine,
                                  self.store, mode=mode)

    def _prefetch_loop(self, interval_s: float) -> None:
        while not self._prefetch_stop.wait(interval_s):
            try:
                self.prefetch_now()
            except Exception:
                pass                    # speculation must not die either

    def prefetch_now(self) -> list:
        """One prefetch cycle: drain the read log, warm the predicted
        top-k.  Safe to call with no prefetcher configured (no-op)."""
        if self.prefetcher is None:
            return []
        return self.prefetcher.prefetch()

    # ------------------------------------------------------------- admin
    def stats(self) -> dict:
        with self._cv:
            out = dict(self._stats)
            out["queued"] = self._qsize
            out["executing"] = len(self._executing_keys)
            out["per_tenant"] = {k: dict(v)
                                 for k, v in self._tenant_stats.items()}
        out["store"] = dict(self.store.stats)
        out["quarantined"] = self.store.stats["quarantined"]
        if self.prefetcher is not None:
            out["prefetch"] = self.prefetcher.stats()
        return out

    def stop(self, drain: bool = True, timeout: Optional[float] = None):
        """Shut down.  ``drain=True`` finishes queued work first; else
        queued tickets fail with ServiceClosed.  Always flushes the
        store (a durability point) and rotates the journal."""
        deadline = time.time() + timeout if timeout is not None else None
        with self._cv:
            if not drain:
                for q in self._queues.values():
                    while q:
                        t = q.popleft()
                        self._qsize -= 1
                        self._finish_locked(
                            t, error=ServiceClosed("service stopping"))
            while self._qsize or self._executing_keys:
                remaining = None if deadline is None \
                    else max(deadline - time.time(), 0.001)
                if not self._cv.wait(remaining):
                    break
            self._closed = True
            self._cv.notify_all()
        if self._maintain_thread is not None:
            self._maintain_stop.set()
            self._maintain_thread.join(timeout=5)
        if self._prefetch_thread is not None:
            self._prefetch_stop.set()
            self._prefetch_thread.join(timeout=5)
        for w in self._workers:
            w.join(timeout=10)
        flush_err = None
        try:
            self.store.flush()
        except ArtifactError as e:
            flush_err = e
        if self.journal is not None:
            self.journal.rotate(self.repo)
            self.journal.close()
        if flush_err is not None:
            raise flush_err
