"""Concurrent multi-tenant ReStore service (DESIGN.md §13).

``ReStoreService`` runs whole workflows on a worker pool over one shared
catalog/store/repository; ``RepositoryJournal`` makes repository state
crash-durable; ``FaultInjector`` drives the seeded fault-injection
suites against the store's IO choke points.
"""
from .faults import FaultInjector, FaultSchedule           # noqa: F401
from .journal import RepositoryJournal, replay_journal     # noqa: F401
from .service import (ReStoreService, ServiceOverloaded,   # noqa: F401
                      ServiceTimeout, Ticket)
