"""Sharding-spec inference for parameters, optimizer states, batches and
decode caches.

Rule-based tensor parallelism over the "model" axis, data parallelism over
("pod", "data"), and a ZeRO-1 extension that additionally shards optimizer
states (and optionally the bf16 params' master copies) over the DP axes on
the largest still-unsharded, divisible dimension.

Every rule checks divisibility; anything that doesn't divide cleanly is
replicated — the dry-run then proves the whole (arch x shape x mesh) cell
lowers and compiles.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.config import ModelConfig
from .mesh import dp_axes

# leaf-name classes: which dim (from the right) gets the "model" axis
_SHARD_LAST = {"wq", "wk", "wv", "wg", "wu", "wuq", "wuk", "wuv", "up",
               "in_proj", "dt_proj", "lm_head", "wi", "wf", "wz", "wo_gate"}
_SHARD_FIRST = {"wo", "wd", "down", "out_proj", "x_proj"}
_BIAS_LIKE = {"bq", "bk", "bv", "conv_b", "dt_bias", "D", "conv_w",
              "A_log"}
_REPLICATE = {"ln1", "ln2", "ln_f", "ln_enc", "ln_x", "q_norm", "k_norm",
              "kv_norm", "gn", "router", "bi", "bf", "bz", "bo",
              "step"}


def _divisible(n: int, mesh: Mesh, axis) -> bool:
    size = 1
    for a in (axis if isinstance(axis, tuple) else (axis,)):
        size *= mesh.shape[a]
    return n % size == 0 and n >= size


def param_spec(path: Tuple[str, ...], shape: Tuple[int, ...],
               mesh: Mesh) -> P:
    name = path[-1]
    nd = len(shape)
    # MoE expert weights are (..., E, d, f): 4-D when layer-stacked, 3-D
    # never (dense MLPs are (L', d, f)) — require the expert dim present
    in_expert = any(p in ("ffn",) for p in path) and nd >= 4 and \
        name in ("wg", "wu", "wd")

    def spec_with(dim_from_right: int):
        dim = nd - dim_from_right
        if dim < 0 or not _divisible(shape[dim], mesh, "model"):
            return P()
        out = [None] * nd
        out[dim] = "model"
        return P(*out)

    if name == "embed":
        # vocab-sharded embedding table
        if _divisible(shape[0], mesh, "model"):
            return P("model", *([None] * (nd - 1)))
        return P()
    if name in _REPLICATE or name in _BIAS_LIKE and nd <= 2:
        return P()
    if in_expert:
        # experts over "model" (expert parallelism): dim -3
        return spec_with(3)
    if name in _SHARD_LAST:
        return spec_with(1)
    if name in _SHARD_FIRST:
        return spec_with(2)
    if name in _BIAS_LIKE:
        return P()
    return P()


def param_specs(cfg: ModelConfig, params_shape, mesh: Mesh):
    """Tree of PartitionSpec mirroring the params tree."""
    def walk(tree, path):
        if isinstance(tree, dict):
            return {k: walk(v, path + (k,)) for k, v in tree.items()}
        if isinstance(tree, tuple):
            return tuple(walk(v, path + (str(i),))
                         for i, v in enumerate(tree))
        return param_spec(path, tree.shape, mesh)
    return walk(params_shape, ())


def zero_extend(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """ZeRO-1: add DP sharding on the largest unsharded divisible dim."""
    dp = dp_axes(mesh)
    if not dp:
        return spec
    entries = list(spec) + [None] * (len(shape) - len(spec))
    best, best_size = None, 0
    for i, (s, n) in enumerate(zip(entries, shape)):
        if s is None and _divisible(n, mesh, dp) and n > best_size:
            best, best_size = i, n
    if best is None:
        return spec
    entries[best] = dp if len(dp) > 1 else dp[0]
    return P(*entries)


def opt_specs(cfg: ModelConfig, params_shape, mesh: Mesh):
    base = param_specs(cfg, params_shape, mesh)

    def walk(spec_tree, shape_tree):
        if isinstance(spec_tree, dict):
            return {k: walk(spec_tree[k], shape_tree[k]) for k in spec_tree}
        if isinstance(spec_tree, tuple):
            return tuple(walk(s, sh) for s, sh in
                         zip(spec_tree, shape_tree))
        return zero_extend(spec_tree, shape_tree.shape, mesh)

    mv = walk(base, params_shape)
    return {"m": mv, "v": mv, "step": P()}


def batch_specs(cfg: ModelConfig, batch_shapes: Dict, mesh: Mesh):
    dp = dp_axes(mesh)
    dp = dp if len(dp) > 1 else (dp[0] if dp else None)

    def spec(name, shape):
        nd = len(shape)
        if name in ("positions", "enc_positions") and nd <= 1:
            return P()
        if name == "positions" and nd == 3:        # m-rope (3, B, S)
            return P(None, dp, None)
        if nd == 0:
            return P()
        if shape[0] == 1:                          # long_500k batch 1
            return P(*([None] * nd))
        return P(dp, *([None] * (nd - 1)))

    return {k: spec(k, v.shape) for k, v in batch_shapes.items()}


def cache_spec(path, shape: Tuple[int, ...], cfg: ModelConfig, mesh: Mesh):
    """Decode caches: (L', B, ...).  Batch over DP when divisible; the
    longest remaining divisible dim (heads or sequence) over "model"."""
    dp = dp_axes(mesh)
    nd = len(shape)
    entries = [None] * nd
    if nd >= 2 and _divisible(shape[1], mesh, dp):
        entries[1] = dp if len(dp) > 1 else dp[0]
    # choose a model-sharded dim among the rest (prefer heads, then seq)
    for dim in range(2, nd):
        if _divisible(shape[dim], mesh, "model") and shape[dim] >= 128:
            entries[dim] = "model"
            break
    return P(*entries)


def cache_specs(cfg: ModelConfig, cache_shapes, mesh: Mesh):
    def walk(tree, path):
        if isinstance(tree, dict):
            return {k: walk(v, path + (k,)) for k, v in tree.items()}
        if isinstance(tree, tuple):
            return tuple(walk(v, path + (str(i),))
                         for i, v in enumerate(tree))
        return cache_spec(path, tree.shape, cfg, mesh)
    return walk(cache_shapes, ())


def to_named(tree_specs, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree_specs,
        is_leaf=lambda x: isinstance(x, P))
