"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches JAX device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any JAX
import, and tests/benches must keep seeing 1 device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi-pod adds a leading pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (real or forced-host) devices exist —
    used by distributed tests and the CPU examples."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    return jax.make_mesh((data, model), ("data", "model"))


def dp_axes(mesh) -> tuple:
    """The data-parallel axes of a mesh (pod folds into DP)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def tp_axis(mesh) -> str:
    return "model"
