"""Production mesh construction + shard_map version compatibility.

Mesh builders are FUNCTIONS (not module-level constants) so importing
this module never touches JAX device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any JAX
import, and tests/benches must keep seeing 1 device.
"""
from __future__ import annotations

import jax


def shard_map(f, mesh, in_specs, out_specs, check: bool = False):
    """Version-portable ``shard_map``.

    Newer JAX exposes ``jax.shard_map`` with the replication check named
    ``check_vma``; on older releases (our pinned CI floor) the function
    lives in ``jax.experimental.shard_map`` and the same knob is
    ``check_rep``.  Every shard_map in this repo goes through here so an
    API rename surfaces in exactly one place."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check)


def make_data_mesh(n_shards: int, axis: str = "data"):
    """1-D data mesh over the first ``n_shards`` devices — the MapReduce
    scale-out axis of the relational engine (DESIGN.md §11)."""
    n = len(jax.devices())
    assert n_shards <= n, (n_shards, n)
    return jax.make_mesh((n_shards,), (axis,))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi-pod adds a leading pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (real or forced-host) devices exist —
    used by distributed tests and the CPU examples."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    return jax.make_mesh((data, model), ("data", "model"))


def dp_axes(mesh) -> tuple:
    """The data-parallel axes of a mesh (pod folds into DP)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def tp_axis(mesh) -> str:
    return "model"
