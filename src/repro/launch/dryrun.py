"""Multi-pod dry-run: prove every (architecture x input-shape x mesh)
cell lowers and compiles under the production sharding config, and emit
the compiled-cost numbers the roofline analysis consumes.

MUST set XLA_FLAGS before ANY other import (jax locks the device count on
first init).  Do not copy these lines into conftest.py or pyproject —
smoke tests and benches must see 1 device.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ruff: noqa: E402
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from ..configs import ARCH_IDS, get_config
from ..models.api import SHAPES, build, shape_applicable
from ..train.optimizer import AdamW
from .mesh import make_production_mesh
from .sharding import (batch_specs, cache_specs, opt_specs, param_specs,
                       to_named)

_DTYPE_BYTES = {
    "f32": 4, "f16": 2, "bf16": 2, "f64": 8, "s32": 4, "u32": 4,
    "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8, "s16": 2, "u16": 2,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# --opt: enable the manually-distributed layer implementations
# (shard_map MoE, sharded decode attention) -- EXPERIMENTS.md §Perf
OPTIMIZED = False


def parse_collective_bytes(hlo_text: str):
    """Sum result-shape bytes of every collective op in post-SPMD HLO."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    shape_re = re.compile(r"=\s*(?:\()?\s*(\w+)\[([\d,]*)\]")
    for line in hlo_text.splitlines():
        s = line.strip()
        m = shape_re.search(s)
        if not m:
            continue
        op = None
        for k in _COLLECTIVES:
            if re.search(rf"\b{k}(-start)?\(", s):
                op = k
                break
        if op is None or f"{op}-done" in s:
            continue
        dt, dims = m.group(1), m.group(2)
        nbytes = _DTYPE_BYTES.get(dt, 4)
        for d in dims.split(","):
            if d:
                nbytes *= int(d)
        out[op] += nbytes
        counts[op] += 1
    return out, counts


def _cost_dict(compiled):
    try:
        c = compiled.cost_analysis()
        if isinstance(c, (list, tuple)):
            c = c[0]
        return {k: float(v) for k, v in c.items()
                if isinstance(v, (int, float))}
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}


def _memory_dict(compiled):
    try:
        m = compiled.memory_analysis()
        keys = ("argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes",
                "alias_size_in_bytes")
        return {k: int(getattr(m, k)) for k in keys if hasattr(m, k)}
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}


def _compile_step(cfg, shape, mesh):
    """Lower + compile the step for (cfg, shape) on mesh."""
    from ..models import dist
    dist.set_mesh(mesh if OPTIMIZED else None)
    dist.set_optimized(OPTIMIZED)
    model = build(cfg)
    seq, gbs, kind = SHAPES[shape]
    params_shapes = model.init_shapes(jax.random.PRNGKey(0))
    p_shard = to_named(param_specs(cfg, params_shapes, mesh), mesh)

    with mesh:
        if kind == "train":
            # bf16 optimizer states for the >=200B archs (fits one pod)
            state_dtype = ("bfloat16" if cfg.total_params() > 1.5e11
                           else "float32")
            opt = AdamW(state_dtype=state_dtype)
            opt_shapes = jax.eval_shape(opt.init, params_shapes)
            o_shard = to_named(opt_specs(cfg, params_shapes, mesh), mesh)
            batch = model.input_specs(shape)
            b_shard = to_named(batch_specs(cfg, batch, mesh), mesh)

            def train_step(params, opt_state, b):
                (tot, (loss, aux)), grads = jax.value_and_grad(
                    model.loss_fn, has_aux=True)(params, b)
                params, opt_state, gnorm = opt.update(grads, opt_state,
                                                      params)
                return params, opt_state, loss, gnorm

            lowered = jax.jit(
                train_step,
                in_shardings=(p_shard, o_shard, b_shard),
                out_shardings=(p_shard, o_shard, None, None),
            ).lower(params_shapes, opt_shapes, batch)

        elif kind == "prefill":
            spec = model.input_specs(shape)
            batch, cache = spec["batch"], spec["cache"]
            b_shard = to_named(batch_specs(cfg, batch, mesh), mesh)
            c_shard = to_named(cache_specs(cfg, cache, mesh), mesh)
            lowered = jax.jit(
                lambda params, b, c: model.prefill(params, b, c),
                in_shardings=(p_shard, b_shard, c_shard),
                out_shardings=(None, c_shard),
            ).lower(params_shapes, batch, cache)

        else:  # decode
            spec = model.input_specs(shape)
            batch, cache, index = (spec["batch"], spec["cache"],
                                   spec["index"])
            b_shard = to_named(batch_specs(cfg, batch, mesh), mesh)
            c_shard = to_named(cache_specs(cfg, cache, mesh), mesh)
            lowered = jax.jit(
                lambda params, b, c, i: model.decode_step(params, b, c, i),
                in_shardings=(p_shard, b_shard, c_shard, None),
                out_shardings=(None, c_shard),
            ).lower(params_shapes, batch, cache, index)

        compiled = lowered.compile()
    return lowered, compiled


# ---------------------------------------------------------------------------
# Loop-aware cost extrapolation.  XLA's HloCostAnalysis counts while-loop
# bodies ONCE (verified: depth 1/2/4 compiles report identical flops), so
# per-cell we also compile depth = 1 and 2 superblock-periods and
# extrapolate the per-period delta to the full depth.


def _depth_variant(cfg, k: int, seq: int = 4096):
    """Depth-k-periods, UNROLLED (scan_layers=False) so every layer's ops
    are visible to cost analysis.  The Mamba chunk loop also unrolls in
    this mode (ssm.py); chunk size scales with the sequence so the
    unrolled body count stays ~8 per layer."""
    import dataclasses as _dc
    from ..models.lm import block_period
    kw = dict(scan_layers=False)
    if cfg.ssm is not None:
        kw["ssm"] = _dc.replace(cfg.ssm, chunk=max(256, seq // 8))
    if cfg.family == "encdec":
        return cfg.with_(n_layers=k, n_encoder_layers=k, **kw)
    return cfg.with_(n_layers=k * block_period(cfg), **kw)


def _n_periods(cfg) -> int:
    from ..models.lm import block_period
    if cfg.family == "encdec":
        return cfg.n_layers
    return cfg.n_layers // block_period(cfg)


def _cell_cost(cfg, shape, mesh) -> dict:
    _, compiled = _compile_step(cfg, shape, mesh)
    cost = _cost_dict(compiled)
    cb, cc = parse_collective_bytes(compiled.as_text())
    return {"flops": cost.get("flops", 0.0),
            "bytes": cost.get("bytes accessed", 0.0),
            "transcendentals": cost.get("transcendentals", 0.0),
            "collective_bytes": cb, "collective_counts": cc}


def extrapolated_cost(cfg, shape, mesh) -> dict:
    seq = SHAPES[shape][0]
    c1 = _cell_cost(_depth_variant(cfg, 1, seq), shape, mesh)
    c2 = _cell_cost(_depth_variant(cfg, 2, seq), shape, mesh)
    n = _n_periods(cfg)

    def ext(a, b):
        return a + (n - 1) * (b - a)

    out = {"flops": ext(c1["flops"], c2["flops"]),
           "bytes": ext(c1["bytes"], c2["bytes"]),
           "transcendentals": ext(c1["transcendentals"],
                                  c2["transcendentals"])}
    out["collective_bytes"] = {
        k: int(ext(c1["collective_bytes"][k], c2["collective_bytes"][k]))
        for k in c1["collective_bytes"]}
    out["collective_counts"] = {
        k: int(ext(c1["collective_counts"][k], c2["collective_counts"][k]))
        for k in c1["collective_counts"]}
    out["method"] = ("per-period differencing over depth-1/-2 compiles, "
                     f"extrapolated to {n} periods")
    return out


def lower_cell(arch, shape, multi_pod=False, save_hlo=None,
               extract_cost=True):
    cfg = get_config(arch)
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    seq, gbs, kind = SHAPES[shape]
    t0 = time.time()
    lowered, compiled = _compile_step(cfg, shape, mesh)
    t_compile = time.time() - t0

    hlo = compiled.as_text()
    coll_bytes, coll_counts = parse_collective_bytes(hlo)
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)

    report = {
        "arch": arch, "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "ok",
        "compile_s": round(t_compile, 1),
        "cost_raw": _cost_dict(compiled),
        "memory": _memory_dict(compiled),
        "collective_bytes_raw": coll_bytes,
        "collective_counts_raw": coll_counts,
        "total_params": cfg.total_params(),
        "active_params": cfg.active_params(),
        "seq": seq, "global_batch": gbs, "kind": kind,
    }
    if extract_cost:
        report["cost_extrapolated"] = extrapolated_cost(cfg, shape, mesh)
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ARCH_IDS + [None])
    ap.add_argument("--shape", default=None,
                    choices=list(SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="all (arch x shape) cells for the chosen mesh")
    ap.add_argument("--out-dir", default="experiments/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--no-cost", action="store_true",
                    help="skip the depth-variant cost extrapolation "
                         "(multi-pod pass only proves compilation)")
    ap.add_argument("--opt", action="store_true",
                    help="enable the beyond-baseline distributed layer "
                         "implementations (EXPERIMENTS.md §Perf)")
    args = ap.parse_args()
    global OPTIMIZED
    OPTIMIZED = args.opt

    os.makedirs(args.out_dir, exist_ok=True)
    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape
        cells = [(args.arch, args.shape)]

    failures = 0
    for arch, shape in cells:
        mesh_tag = "2x16x16" if args.multi_pod else "16x16"
        tag = f"{arch}_{shape}_{mesh_tag}" + ("_opt" if args.opt else "")
        hlo_path = (os.path.join(args.out_dir, tag + ".hlo.txt")
                    if args.save_hlo else None)
        try:
            rep = lower_cell(arch, shape, args.multi_pod, hlo_path,
                             extract_cost=not args.no_cost)
        except Exception as e:
            rep = {"arch": arch, "shape": shape, "mesh": mesh_tag,
                   "status": "FAILED", "error": str(e)[-2000:],
                   "traceback": traceback.format_exc()[-4000:]}
            failures += 1
        with open(os.path.join(args.out_dir, tag + ".json"), "w") as f:
            json.dump(rep, f, indent=1)
        status = rep["status"]
        extra = ""
        if status == "ok":
            extra = (f"compile={rep['compile_s']}s "
                     f"flops={rep['cost_raw'].get('flops', 0):.3g}")
        print(f"[{status:>7s}] {tag} {extra}", flush=True)
    print(f"done: {len(cells)} cells, {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
