"""End-to-end training driver with fault tolerance.

Features exercised by examples/train_lm.py and the integration tests:
  * data from the ReStore-backed pipeline (repeated runs reuse stages);
  * jitted train step, sharded over whatever mesh the host offers;
  * atomic checkpoints every --ckpt-every steps; on start, resume from
    the newest valid checkpoint and skip the data stream ahead
    (deterministic batcher => exact-once sample consumption);
  * --simulate-failure N kills the process at step N (the fault-tolerance
    test restarts the driver and checks the loss curve continues);
  * elastic: checkpoints are mesh-agnostic, restore re-shards.
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCH_IDS, get_config
from ..core.restore import ReStore
from ..models.api import build
from ..store.artifacts import ArtifactStore, Catalog
from ..train.checkpoint import latest_step, restore_checkpoint, \
    save_checkpoint
from ..train.data import batches_from_table, run_pipeline, synthetic_corpus
from ..train.optimizer import AdamW


def train(arch: str = "qwen3-1.7b", steps: int = 50, batch_size: int = 8,
          seq_len: int = 64, lr: float = 3e-4, ckpt_every: int = 10,
          ckpt_dir: str = "/tmp/repro_ckpt", simulate_failure: int = -1,
          scale: float = 1.0, log_every: int = 5, data_dir=None,
          quiet: bool = False):
    cfg = get_config(arch, smoke=True)
    if scale == 100.0:  # "100m" preset: a genuine ~100M-param model
        cfg = cfg.with_(n_layers=12, d_model=640, n_heads=10,
                        n_kv_heads=5, head_dim=64, d_ff=2560,
                        vocab_size=32768)
    elif scale != 1.0:
        cfg = cfg.with_(d_model=int(cfg.d_model * scale),
                        d_ff=int(cfg.d_ff * scale),
                        vocab_size=max(cfg.vocab_size, 8192))
    model = build(cfg)
    opt = AdamW(lr=lr)

    # ---- data through the ReStore pipeline --------------------------------
    store = ArtifactStore(root=data_dir)
    catalog = Catalog(store)
    restore = ReStore(catalog, store, heuristic="aggressive")
    corpus = synthetic_corpus(n_docs=256, seq_len=seq_len + 1,
                              vocab=cfg.vocab_size)
    catalog.register("corpus", corpus)
    table, report = run_pipeline(restore, corpus)
    if not quiet:
        print(f"pipeline: {report.n_executed} executed, "
              f"{report.n_reused} artifacts reused")
    batches = batches_from_table(table, batch_size, seq_len)

    # ---- init or resume ----------------------------------------------------
    params = model.init(jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    start_step = 0
    last = latest_step(ckpt_dir)
    if last is not None:
        (params, opt_state), manifest = restore_checkpoint(
            ckpt_dir, last, (params, opt_state))
        start_step = manifest["step"]
        if not quiet:
            print(f"resumed from checkpoint step {start_step}")
    for _ in range(start_step):          # deterministic skip-ahead
        next(batches)

    # ---- jitted step -------------------------------------------------------
    @jax.jit
    def train_step(params, opt_state, tokens, labels):
        def loss_fn(p):
            batch = {"tokens": tokens, "labels": labels,
                     "positions": jnp.arange(tokens.shape[1],
                                             dtype=jnp.int32)}
            return model.loss_fn(p, batch)
        (tot, (loss, aux)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        params, opt_state, gnorm = opt.update(grads, opt_state, params)
        return params, opt_state, loss, gnorm

    losses = []
    for step in range(start_step, steps):
        tokens, labels = next(batches)
        t0 = time.time()
        params, opt_state, loss, gnorm = train_step(
            params, opt_state, jnp.asarray(tokens), jnp.asarray(labels))
        loss = float(loss)
        losses.append(loss)
        if not quiet and (step % log_every == 0 or step == steps - 1):
            print(f"step {step:4d} loss {loss:7.4f} gnorm {float(gnorm):6.2f}"
                  f" {time.time() - t0:5.2f}s")
        if (step + 1) % ckpt_every == 0 or step == steps - 1:
            save_checkpoint(ckpt_dir, step + 1, (params, opt_state),
                            extra={"arch": arch, "loss": loss})
        if simulate_failure == step:
            print(f"simulating node failure at step {step}", flush=True)
            os._exit(17)     # hard kill: no cleanup, like a real failure
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b", choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--simulate-failure", type=int, default=-1)
    ap.add_argument("--scale", type=float, default=1.0)
    args = ap.parse_args()
    train(**{k.replace("-", "_"): v for k, v in vars(args).items()})


if __name__ == "__main__":
    main()
