"""Dry-run for the RELATIONAL engine on the production mesh: lower +
compile a distributed GROUPBY job (hash shuffle over ICI) at warehouse
scale — the multi-node proof for the paper's own workload.

Same contract as launch/dryrun.py: XLA_FLAGS first.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ruff: noqa: E402
import argparse
import json

import jax
import jax.numpy as jnp

from ..dataflow.shuffle import distributed_groupby
from ..dataflow.table import Table
from .dryrun import _cost_dict, _memory_dict, parse_collective_bytes
from .mesh import make_production_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=1 << 24)   # 16M rows/pod
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun/dataflow_groupby.json")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    n = args.rows
    S = jax.ShapeDtypeStruct
    cols = {"key": S((n, 20), jnp.uint8),       # page_views.user
            "val": S((n,), jnp.float32)}        # estimated_revenue
    table = Table(cols, S((n,), jnp.bool_))
    keys, aggs = ["key"], {"total": ("sum", "val"),
                           "cnt": ("count", "val")}

    from jax.sharding import NamedSharding, PartitionSpec as P
    row_shard = NamedSharding(mesh, P("data"))
    in_sh = Table({k: row_shard for k in cols}, row_shard)

    with mesh:
        lowered = jax.jit(
            lambda t: distributed_groupby(t, keys, aggs, mesh),
            in_shardings=(in_sh,),
        ).lower(table)
        compiled = lowered.compile()

    cb, cc = parse_collective_bytes(compiled.as_text())
    rep = {"rows": n, "mesh": "2x16x16" if args.multi_pod else "16x16",
           "status": "ok", "cost": _cost_dict(compiled),
           "memory": _memory_dict(compiled),
           "collective_bytes": cb, "collective_counts": cc}
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(rep, f, indent=1)
    coll = sum(cb.values())
    print(f"[ok] dataflow groupby {n} rows on {rep['mesh']}: "
          f"collective={coll:.3g}B/dev "
          f"(all-to-all={cb['all-to-all']:.3g}) "
          f"temp={rep['memory'].get('temp_size_in_bytes', 0) / 2**30:.2f}GiB")


if __name__ == "__main__":
    main()
